"""Paper Figs. 9 & 10: FETI preprocessing across dual-operator approaches
and the amortization points.

Approaches benchmarked (paper Table 2, mapped to this framework):
  impl            — numerical factorization only (implicit dual op)
  expl_dense      — factorization + dense §3.1 SC assembly   (= expl_cuda)
  expl_opt        — factorization + sparsity-utilizing SC    (= expl_gpu_opt)
  expl_dirichlet  — expl_opt + the dirichlet preconditioner's primal
                    boundary Schur stage (docs/preconditioners.md)

The lumped-vs-dirichlet rows report PCPG iterations, preconditioner
apply time, the dirichlet stage's preprocessing overhead, and the
amortization point WITH that overhead in the numerator
(``FetiSolver.amortization_report(t_dirichlet_s=...)``).

Amortization point = preprocessing overhead / per-iteration saving
(implicit TRSV pair vs explicit GEMV), reported per subdomain size — the
paper's headline claim is ≈10 iterations, flat across sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SchurAssemblyConfig
from repro.fem import decompose_problem
from repro.feti import FetiConfig, FetiSolver
from repro.feti.assembly import preprocess_cluster
from repro.feti.operator import (
    dirichlet_preconditioner,
    explicit_dual_apply,
    implicit_dual_apply,
    lumped_preconditioner,
)
from benchmarks.common import emit, fmt_bytes, time_fn


def run(cases=(("heat", 2, (2, 2), (8, 8)), ("heat", 2, (2, 2), (16, 16)),
               ("heat", 3, (2, 2, 1), (4, 4, 4)),
               ("heat", 3, (2, 2, 1), (6, 6, 6)),
               # elasticity: 2-3 DOFs/node, kernel dim 3/6 — heat-vs-
               # elasticity preprocessing cost at comparable DOF counts
               ("elasticity", 2, (2, 2), (8, 8)),
               ("elasticity", 3, (2, 2, 1), (3, 3, 3))),
        bs: int = 16, reps: int = 3,
        n_rhs_list=(1, 4, 16, 64)) -> list[tuple]:
    rows = []
    for problem, dim, grid, eps in cases:
        prob = decompose_problem(problem, dim, grid, eps)
        n = prob.subdomains[0].n
        tag = f"{dim}d/n{n}" if problem == "heat" else f"{dim}d-ela/n{n}"
        # storage pinned to dense: these are the dense-stored references
        # the preproc_expl_packed row compares against (REPRO_STORAGE must
        # not flip them under the CI packed lane)
        cfg_opt = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs,
                                      storage="dense")
        cfg_dense = SchurAssemblyConfig(trsm_variant="dense",
                                        syrk_variant="dense",
                                        block_size=bs, rhs_block_size=bs,
                                        prune=False, storage="dense")

        import numpy as np

        from repro.feti.assembly import make_cluster_preprocessor
        from repro.fem.regularization import fixing_dofs_regularization

        def preprocess_time(cfg, explicit, dirichlet=False,
                            share_factor="auto"):
            """Time the COMPILED preprocessing (pattern fixed, values new —
            the paper's multi-step regime)."""
            fc = FetiConfig(
                schur=cfg,
                mode="explicit" if explicit else "implicit",
                preconditioner="dirichlet" if dirichlet else "lumped",
                share_factor=share_factor)
            static, prep = make_cluster_preprocessor(prob, fc)
            np_ = static["node_perm"]
            Kp = np.stack([
                fixing_dofs_regularization(sd.K, sd.fixing_dofs)[np_][:, np_]
                for sd in prob.subdomains
            ])
            Btp = np.stack([sd.Bt[np_] for sd in prob.subdomains])
            args = [jnp.asarray(Kp), jnp.asarray(Btp)]
            if dirichlet:
                from repro.feti.dirichlet import own_boundary_masks

                split = static["split"]
                dperm = split.dperm
                Kd = np.stack([sd.K for sd in prob.subdomains]
                              )[:, dperm][:, :, dperm]
                if static["share"]:
                    # shared interior factor: the stage streams only K_bb
                    # (K_ib comes off the dual stage's permuted K input)
                    Kd = Kd[:, split.n_i:, split.n_i:]
                args += [jnp.asarray(Kd),
                         jnp.asarray(own_boundary_masks(prob, split))]
            idx = 2 if dirichlet else (1 if explicit else 0)
            us = time_fn(lambda *a: prep(*a)[idx], *args, reps=reps)
            st = preprocess_cluster(prob, fc)
            return st, us

        import dataclasses

        cfg_packed = dataclasses.replace(cfg_opt, storage="packed")

        st_impl, t_impl = preprocess_time(cfg_opt, explicit=False)
        _, t_expl_dense = preprocess_time(cfg_dense, explicit=True)
        st_expl, t_expl_opt = preprocess_time(cfg_opt, explicit=True)
        st_pack, t_expl_packed = preprocess_time(cfg_packed, explicit=True)
        rows.append((f"feti/{tag}/preproc_impl", t_impl, fmt_bytes(st_impl)))
        rows.append((f"feti/{tag}/preproc_expl_dense", t_expl_dense,
                     f"slowdown_vs_impl={t_expl_dense / t_impl:.2f}"))
        rows.append((f"feti/{tag}/preproc_expl_opt", t_expl_opt,
                     f"slowdown_vs_impl={t_expl_opt / t_impl:.2f};"
                     + fmt_bytes(st_expl)))
        rows.append((f"feti/{tag}/preproc_expl_packed", t_expl_packed,
                     f"slowdown_vs_impl={t_expl_packed / t_impl:.2f};"
                     + fmt_bytes(st_pack)))

        # per-iteration dual operator application
        nl = prob.n_lambda
        lam = jnp.zeros((nl,))
        imp = jax.jit(lambda p: implicit_dual_apply(
            st_impl.L, st_impl.Btp, st_impl.lambda_ids, nl, p))
        exp = jax.jit(lambda p: explicit_dual_apply(
            st_expl.F, st_expl.lambda_ids, nl, p))
        t_it_imp = time_fn(imp, lam, reps=reps)
        t_it_exp = time_fn(exp, lam, reps=reps)
        overhead = t_expl_opt - t_impl
        gain = t_it_imp - t_it_exp
        amort = overhead / gain if gain > 0 else float("inf")
        rows.append((f"feti/{tag}/iter_implicit", t_it_imp, ""))
        rows.append((f"feti/{tag}/iter_explicit", t_it_exp,
                     f"amortization_iters={amort:.1f}"))

        # end-to-end sanity: solve and report iterations
        solver = FetiSolver(prob, cfg_opt)
        sol = solver.solve(tol=1e-8, max_iter=500)
        rows.append((f"feti/{tag}/pcpg_iterations", float(sol.iterations),
                     f"converged={sol.converged}"))

        # ---- multi-RHS block solve service (ISSUE 6) ----
        # The primary number is the warm END-TO-END wall time per
        # delivered solution (RHS setup + block PCPG + α/u recovery,
        # preprocessing excluded): the per-batch fixed costs amortize
        # over the columns and the (S, m, m) operator stack streams once
        # per *block* iteration whatever the column count, so cost per
        # solve collapses as n_rhs grows. Rows reuse the SAME solver
        # (the server pattern of docs/multirhs.md: preprocess once,
        # stream batches); break-even is reported in *solves* via
        # amortization_report(n_rhs=..., iters_per_solve=...).
        import time as _time

        from repro.feti.operator import (
            explicit_dual_apply_many,
            implicit_dual_apply_many,
        )

        for r in n_rhs_list:
            loads = prob.load_cases(r, kind="random", seed=0)
            solver.solve_many(loads, tol=1e-8, max_iter=500)  # compile
            t_many, solm = None, None
            for _ in range(reps):
                t0 = _time.perf_counter()
                sm = solver.solve_many(loads, tol=1e-8, max_iter=500)
                t = (_time.perf_counter() - t0) * 1e6
                if t_many is None or t < t_many:
                    t_many, solm = t, sm
            Lam = jnp.zeros((nl, r))
            imp_m = jax.jit(lambda p: implicit_dual_apply_many(
                st_impl.L, st_impl.Btp, st_impl.lambda_ids, nl, p))
            exp_m = jax.jit(lambda p: explicit_dual_apply_many(
                st_expl.F, st_expl.lambda_ids, nl, p))
            t_blk_imp = time_fn(imp_m, Lam, reps=reps)
            t_blk_exp = time_fn(exp_m, Lam, reps=reps)
            rep_m = solver.amortization_report(
                t_assembly_s=(t_expl_opt - t_impl) * 1e-6,
                t_implicit_iter_s=t_blk_imp * 1e-6,
                t_explicit_iter_s=t_blk_exp * 1e-6,
                n_rhs=r,
                iters_per_solve=float(np.mean(np.asarray(solm.iterations))),
            )
            ai = rep_m["solve_iter_counts"]["arithmetic_intensity"]
            rows.append((
                f"feti/{tag}/solve_many_r{r}",
                t_many / r,  # warm end-to-end wall time per solve, us
                f"total_us={t_many:.0f};"
                f"pcpg_us={solm.timings['solve_many_s'] * 1e6:.0f};"
                f"block_iters={int(solm.block_iterations)};"
                f"blockiter_expl_us={t_blk_exp:.1f};"
                f"blockiter_impl_us={t_blk_imp:.1f};"
                f"amort_solves={rep_m['amortization_solves']:.1f};"
                f"analytic_ai={ai:.2f}"))

        # ---- lumped vs dirichlet preconditioner (ISSUE 5) ----
        st_dir, t_expl_dir = preprocess_time(cfg_opt, explicit=True,
                                             dirichlet=True)
        t_dir_stage = t_expl_dir - t_expl_opt  # the stage's extra cost
        apply_l = jax.jit(lambda w: lumped_preconditioner(
            st_expl.K, st_expl.Btp, st_expl.lambda_ids, nl, w))
        apply_d = jax.jit(lambda w: dirichlet_preconditioner(
            st_dir.Sb, st_dir.Btb, st_dir.lambda_ids, nl, w))
        t_ap_l = time_fn(apply_l, lam, reps=reps)
        t_ap_d = time_fn(apply_d, lam, reps=reps)
        solver_dir = FetiSolver(prob, FetiConfig(
            schur=cfg_opt, preconditioner="dirichlet"))
        sol_dir = solver_dir.solve(tol=1e-8, max_iter=500)
        rep = solver_dir.amortization_report(
            t_assembly_s=(t_expl_opt - t_impl) * 1e-6,
            t_implicit_iter_s=t_it_imp * 1e-6,
            t_explicit_iter_s=t_it_exp * 1e-6,
            t_dirichlet_s=t_dir_stage * 1e-6,
        )
        rows.append((f"feti/{tag}/precond_lumped", t_ap_l,
                     f"pcpg_iters={sol.iterations}"))
        rows.append((
            f"feti/{tag}/precond_dirichlet", t_ap_d,
            f"pcpg_iters={sol_dir.iterations};"
            f"iter_saving_vs_lumped={sol.iterations - sol_dir.iterations};"
            f"dirichlet_stage_us={t_dir_stage:.1f};"
            f"amort_iters_with_dirichlet="
            f"{rep['amortization_iterations']:.1f};"
            f"Sb_bytes={st_dir.device_bytes()['Sb']}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
