"""LM-side microbenchmarks: smoke-scale train/decode step times per
architecture family (the full-scale numbers live in the dry-run roofline,
results/dryrun.jsonl)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.data import synthetic_batch
from repro.models import init_cache, init_model
from repro.train import (
    OptimizerConfig,
    TrainConfig,
    adamw_init,
    make_decode_step,
    make_train_step,
)
from benchmarks.common import emit, time_fn


def run(reps: int = 3) -> list[tuple]:
    rows = []
    archs = [a for a in list_archs() if not a.startswith("feti")]
    for arch in archs:
        cfg = get_smoke_config(arch)
        params = init_model(jax.random.PRNGKey(0), cfg)
        batch = synthetic_batch(cfg, 4, 32, seed=0)
        tcfg = TrainConfig(optimizer=OptimizerConfig(), remat=False)
        step = jax.jit(make_train_step(cfg, tcfg))
        opt = adamw_init(params, tcfg.optimizer)
        us = time_fn(lambda p, o, b: step(p, o, b)[2]["loss"], params, opt,
                     batch, reps=reps)
        rows.append((f"lm/{arch}/train_step_smoke", us, ""))
        if not cfg.is_encoder_only:
            cache = init_cache(cfg, 4, 64)
            dec = jax.jit(make_decode_step(cfg))
            tok = jnp.zeros((4, 1), jnp.int32)
            us = time_fn(lambda *a: dec(*a)[0], params, tok, cache,
                         jnp.asarray(0, jnp.int32), reps=reps)
            rows.append((f"lm/{arch}/decode_step_smoke", us, ""))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
