"""Shared benchmark utilities: timing, problem factories, CSV emission.

CPU wall-times here are *relative* measurements (the paper's A100 numbers
are not reproducible on this container); every table also reports the
FLOP-model-derived numbers that transfer to the TPU target.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

jax.config.update("jax_enable_x64", True)  # the FETI substrate benches are
#                                            f64 (paper's CPU/GPU regime);
#                                            LM benches pass explicit dtypes

import numpy as np

from repro.core import build_stepped_meta
from repro.fem import (
    assemble_dense,
    element_dofs,
    p1_elasticity_stiffness,
    p1_element_stiffness,
    structured_mesh,
)
from repro.fem.decomposition import _fixing_dofs
from repro.fem.regularization import fixing_dofs_regularization
from repro.sparse import (
    PackedBlockIndex,
    PackedBlocks,
    block_pattern,
    block_symbolic_cholesky,
    matrix_pattern_from_elems,
    nested_dissection_order,
)
from repro.sparse.cholesky import block_cholesky

__all__ = [
    "time_fn",
    "subdomain_problem",
    "emit",
    "HEADER",
    "device_bytes",
    "fmt_bytes",
]

HEADER = "name,us_per_call,derived"


def device_bytes(x) -> int:
    """Device bytes of an array stack or a PackedBlocks stack (0 for None)."""
    if x is None:
        return 0
    if isinstance(x, PackedBlocks):
        return x.nbytes
    x = np.asarray(x) if not hasattr(x, "dtype") else x
    return int(np.prod(x.shape)) * x.dtype.itemsize


def fmt_bytes(st) -> str:
    """``derived``-column fragment reporting the solution-phase stack bytes
    — packed-vs-dense memory shows up in every bench table that carries a
    cluster state."""
    by = st.device_bytes()
    return (f"storage={st.storage};L_bytes={by['L']};K_bytes={by['K']};"
            f"Btp_bytes={by['Btp']};F_bytes={by['F']};"
            f"dense_L_bytes={by['dense_L']}")


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 1) -> float:
    """Median wall-time (µs) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def subdomain_problem(dim: int, elems_per_axis: int, block_size: int,
                      rhs_block_size: int | None = None, seed: int = 0,
                      problem: str = "heat"):
    """One FETI-like subdomain: K_reg (ND-permuted), its factor L, B̃ᵀ in
    factor row order, stepped metadata, and the symbolic block mask.

    ``problem="elasticity"`` builds the node-blocked vector-DOF subdomain
    (2-3 DOFs per node, rigid-body kernel): same node ordering, DOF perm
    and pattern expanded per node block — the block-size ↔ DOFs-per-node
    interplay the elasticity bench rows measure.
    """
    from repro.feti.assembly import expand_node_pattern, expand_node_perm

    shape = (elems_per_axis,) * dim
    mesh = structured_mesh(shape)
    ndpn = 1 if problem == "heat" else dim
    n = mesh.n_nodes * ndpn
    node_shape = tuple(s + 1 for s in shape)
    lstrides = [1]
    for d in range(dim - 1):
        lstrides.append(lstrides[-1] * node_shape[d])
    if problem == "heat":
        Ke = p1_element_stiffness(mesh.coords, mesh.elems)
        edofs = mesh.elems
    else:
        Ke = p1_elasticity_stiffness(mesh.coords, mesh.elems)
        edofs = element_dofs(mesh.elems, dim)
    # heat: the center fixing node; elasticity: the same 3-2-1 fixture
    # the decomposition places (shared helper — layouts can't diverge)
    fix = _fixing_dofs(problem, dim, node_shape, lstrides,
                       fixing_node=mesh.n_nodes // 2)
    K = np.asarray(assemble_dense(n, edofs, Ke))
    K = fixing_dofs_regularization(K, fix)
    perm = expand_node_perm(nested_dissection_order(node_shape), ndpn)
    Kp = K[perm][:, perm]
    pat = expand_node_pattern(
        matrix_pattern_from_elems(mesh.n_nodes, mesh.elems), ndpn)
    pat = pat[perm][:, perm]
    mask = block_symbolic_cholesky(block_pattern(pat, block_size))
    L = np.asarray(block_cholesky(jax.numpy.asarray(Kp), block_size, mask=mask))

    # surface multipliers: ~one per boundary DOF (FETI-like density)
    rng = np.random.default_rng(seed)
    # boundary nodes of the box
    grid = np.meshgrid(*[np.arange(s + 1) for s in shape], indexing="ij")
    idx = np.stack([g.ravel(order="F") for g in grid], axis=1)
    on_surf = np.any((idx == 0) | (idx == np.array(shape)), axis=1)
    surf = np.flatnonzero(on_surf)
    surf_dofs = (surf[:, None] * ndpn + np.arange(ndpn)).reshape(-1)
    # map to permuted row ids
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    rows = inv[surf_dofs]
    m = len(rows)
    Bt = np.zeros((n, m))
    Bt[rows, np.arange(m)] = rng.choice([-1.0, 1.0], m)
    meta = build_stepped_meta(Bt != 0, block_size=block_size,
                              rhs_block_size=rhs_block_size or block_size)
    index = PackedBlockIndex.from_mask(mask, n, block_size)
    return dict(n=n, m=m, K=Kp, L=L, Bt=Bt, meta=meta, mask=mask,
                index=index)


def emit(rows: list[tuple]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
