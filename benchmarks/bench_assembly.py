"""Paper Fig. 8: whole explicit SC assembly — separated (factor given) and
mixed (numerical factorization + assembly together) configurations,
optimized pipeline vs the dense §3.1 baseline, plus the packed-vs-dense
factor-storage comparison (time AND device bytes: the packed layout keeps
only the fill mask's blocks on device, docs/packed_storage.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (
    SchurAssemblyConfig,
    assembly_flops,
    make_assembler,
    schur_dense_baseline,
)
from repro.sparse import block_cholesky_packed, pack_factor
from repro.sparse.cholesky import block_cholesky, block_cholesky_flops
from benchmarks.common import device_bytes, emit, subdomain_problem, time_fn


def run(sizes_2d=(16, 24), sizes_3d=(6, 9), ela_2d=(12, 16), ela_3d=(4, 6),
        bs: int = 32, reps: int = 3,
        stage_graph_cases=((2, (2, 2), (8, 8)), (2, (2, 2), (20, 20)),
                           (3, (2, 1, 1), (3, 3, 3)))) -> list[tuple]:
    rows = []
    cases = [("heat", 2, sizes_2d), ("heat", 3, sizes_3d),
             # elasticity: same node grids are 2-3x the DOFs (node-blocked),
             # so the block-size ↔ DOFs-per-node interplay shows up in the
             # same table at comparable n
             ("elasticity", 2, ela_2d), ("elasticity", 3, ela_3d)]
    for problem, dim, sizes in cases:
        for e in sizes:
            prob = subdomain_problem(dim, e, bs, problem=problem)
            K = jnp.asarray(prob["K"])
            L = jnp.asarray(prob["L"])
            Bt = jnp.asarray(prob["Bt"])
            meta, mask = prob["meta"], prob["mask"]
            n = prob["n"]
            tag = (f"{dim}d/n{n}" if problem == "heat"
                   else f"{dim}d-ela/n{n}")
            # storage pinned: these rows ARE the dense-stored reference the
            # packed rows below compare against (REPRO_STORAGE must not
            # flip them under the CI packed lane)
            cfg = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs,
                                      storage="dense")

            opt = jax.jit(make_assembler(meta, cfg, mask))
            t_sep_opt = time_fn(opt, L, Bt, reps=reps)
            t_sep_dense = time_fn(jax.jit(schur_dense_baseline), L, Bt,
                                  reps=reps)
            rows.append((f"assembly/{tag}/sep_opt", t_sep_opt,
                         f"speedup={t_sep_dense / t_sep_opt:.2f}"))

            def mixed_opt(Kx, Bx):
                Lx = block_cholesky(Kx, bs, mask=mask)
                return make_assembler(meta, cfg, mask)(Lx, Bx)

            def mixed_dense(Kx, Bx):
                Lx = block_cholesky(Kx, bs)
                return schur_dense_baseline(Lx, Bx)

            t_mix_opt = time_fn(jax.jit(mixed_opt), K, Bt, reps=reps)
            t_mix_dense = time_fn(jax.jit(mixed_dense), K, Bt, reps=reps)
            fl = (assembly_flops(meta, cfg)["total"]
                  + block_cholesky_flops(n, bs, mask))
            rows.append((f"assembly/{tag}/mix_opt", t_mix_opt,
                         f"speedup={t_mix_dense / t_mix_opt:.2f};flops={fl}"))

            # packed factor storage: same assembly, factor lives as the
            # fill-mask block stack — report time AND device bytes
            index = prob["index"]
            cfg_p = dataclasses.replace(cfg, storage="packed")
            Lp = jax.block_until_ready(pack_factor(L, index))
            packed = jax.jit(make_assembler(meta, cfg_p, mask))
            t_sep_packed = time_fn(packed, Lp, Bt, reps=reps)
            b_packed, b_dense = device_bytes(Lp), device_bytes(L)
            rows.append((
                f"assembly/{tag}/sep_packed", t_sep_packed,
                f"speedup={t_sep_dense / t_sep_packed:.2f};"
                f"L_bytes={b_packed};dense_L_bytes={b_dense};"
                f"mem_ratio={b_packed / b_dense:.2f}"))

            def mixed_packed(Kx, Bx):
                Lx = block_cholesky_packed(Kx, index)
                return make_assembler(meta, cfg_p, mask)(Lx, Bx)

            t_mix_packed = time_fn(jax.jit(mixed_packed), K, Bt, reps=reps)
            rows.append((
                f"assembly/{tag}/mix_packed", t_mix_packed,
                f"speedup={t_mix_dense / t_mix_packed:.2f};"
                f"mem_ratio={b_packed / b_dense:.2f}"))
    rows += run_stage_graph(cases=stage_graph_cases, reps=max(reps, 3))
    return rows


def run_stage_graph(cases, bs: int = 32, reps: int = 5) -> list[tuple]:
    """ISSUE 7: mixed preprocessing (factorization + BOTH Schur stages)
    through the stage graph with the shared interior factor, against the
    PR-5 two-pipeline baseline (``share_factor=False``: the Dirichlet
    stage refactorizes K_ii). Same compiled-prep timing protocol as
    ``bench_feti`` — pattern fixed, values streamed. The win scales with
    the interior fraction (the saved work is the Dirichlet stage's own
    K_ii factorization plus streaming K_bb instead of the full permuted
    K): ~1.3x on the (2,2)x(20,20) 2D case, nil on small-interior 3D
    boxes."""
    import numpy as np

    from repro.fem.decomposition import decompose_elasticity_problem
    from repro.fem.regularization import fixing_dofs_regularization
    from repro.feti import FetiConfig
    from repro.feti.assembly import make_cluster_preprocessor
    from repro.feti.dirichlet import own_boundary_masks

    rows = []
    for dim, grid, eps in cases:
        prob = decompose_elasticity_problem(dim, grid, eps)
        n = prob.subdomains[0].n
        tag = f"{dim}d-ela/n{n}"
        cfg = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs,
                                  storage="dense")

        def prep_time(share):
            fc = FetiConfig(schur=cfg, preconditioner="dirichlet",
                            share_factor=share)
            static, prep = make_cluster_preprocessor(prob, fc)
            np_ = static["node_perm"]
            split = static["split"]
            Kp = np.stack([
                fixing_dofs_regularization(sd.K, sd.fixing_dofs)[np_][:, np_]
                for sd in prob.subdomains])
            Btp = np.stack([sd.Bt[np_] for sd in prob.subdomains])
            dperm = split.dperm
            Kd = np.stack([sd.K for sd in prob.subdomains]
                          )[:, dperm][:, :, dperm]
            if static["share"]:
                Kd = Kd[:, split.n_i:, split.n_i:]
            args = [jnp.asarray(Kp), jnp.asarray(Btp), jnp.asarray(Kd),
                    jnp.asarray(own_boundary_masks(prob, split))]

            def both_stages(*a):
                _, F, Sb = prep(*a)
                return F, Sb

            return time_fn(both_stages, *args, reps=reps), static["share"]

        t_base, shared0 = prep_time(False)
        t_shared, shared1 = prep_time(True)
        assert not shared0 and shared1
        rows.append((f"assembly/{tag}/mix_two_pipelines", t_base, "baseline"))
        rows.append((f"assembly/{tag}/mix_shared_factor", t_shared,
                     f"speedup={t_base / t_shared:.2f}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
