"""Paper Fig. 8: whole explicit SC assembly — separated (factor given) and
mixed (numerical factorization + assembly together) configurations,
optimized pipeline vs the dense §3.1 baseline, plus the packed-vs-dense
factor-storage comparison (time AND device bytes: the packed layout keeps
only the fill mask's blocks on device, docs/packed_storage.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (
    SchurAssemblyConfig,
    assembly_flops,
    make_assembler,
    schur_dense_baseline,
)
from repro.sparse import block_cholesky_packed, pack_factor
from repro.sparse.cholesky import block_cholesky, block_cholesky_flops
from benchmarks.common import device_bytes, emit, subdomain_problem, time_fn


def run(sizes_2d=(16, 24), sizes_3d=(6, 9), ela_2d=(12, 16), ela_3d=(4, 6),
        bs: int = 32, reps: int = 3) -> list[tuple]:
    rows = []
    cases = [("heat", 2, sizes_2d), ("heat", 3, sizes_3d),
             # elasticity: same node grids are 2-3x the DOFs (node-blocked),
             # so the block-size ↔ DOFs-per-node interplay shows up in the
             # same table at comparable n
             ("elasticity", 2, ela_2d), ("elasticity", 3, ela_3d)]
    for problem, dim, sizes in cases:
        for e in sizes:
            prob = subdomain_problem(dim, e, bs, problem=problem)
            K = jnp.asarray(prob["K"])
            L = jnp.asarray(prob["L"])
            Bt = jnp.asarray(prob["Bt"])
            meta, mask = prob["meta"], prob["mask"]
            n = prob["n"]
            tag = (f"{dim}d/n{n}" if problem == "heat"
                   else f"{dim}d-ela/n{n}")
            # storage pinned: these rows ARE the dense-stored reference the
            # packed rows below compare against (REPRO_STORAGE must not
            # flip them under the CI packed lane)
            cfg = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs,
                                      storage="dense")

            opt = jax.jit(make_assembler(meta, cfg, mask))
            t_sep_opt = time_fn(opt, L, Bt, reps=reps)
            t_sep_dense = time_fn(jax.jit(schur_dense_baseline), L, Bt,
                                  reps=reps)
            rows.append((f"assembly/{tag}/sep_opt", t_sep_opt,
                         f"speedup={t_sep_dense / t_sep_opt:.2f}"))

            def mixed_opt(Kx, Bx):
                Lx = block_cholesky(Kx, bs, mask=mask)
                return make_assembler(meta, cfg, mask)(Lx, Bx)

            def mixed_dense(Kx, Bx):
                Lx = block_cholesky(Kx, bs)
                return schur_dense_baseline(Lx, Bx)

            t_mix_opt = time_fn(jax.jit(mixed_opt), K, Bt, reps=reps)
            t_mix_dense = time_fn(jax.jit(mixed_dense), K, Bt, reps=reps)
            fl = (assembly_flops(meta, cfg)["total"]
                  + block_cholesky_flops(n, bs, mask))
            rows.append((f"assembly/{tag}/mix_opt", t_mix_opt,
                         f"speedup={t_mix_dense / t_mix_opt:.2f};flops={fl}"))

            # packed factor storage: same assembly, factor lives as the
            # fill-mask block stack — report time AND device bytes
            index = prob["index"]
            cfg_p = dataclasses.replace(cfg, storage="packed")
            Lp = jax.block_until_ready(pack_factor(L, index))
            packed = jax.jit(make_assembler(meta, cfg_p, mask))
            t_sep_packed = time_fn(packed, Lp, Bt, reps=reps)
            b_packed, b_dense = device_bytes(Lp), device_bytes(L)
            rows.append((
                f"assembly/{tag}/sep_packed", t_sep_packed,
                f"speedup={t_sep_dense / t_sep_packed:.2f};"
                f"L_bytes={b_packed};dense_L_bytes={b_dense};"
                f"mem_ratio={b_packed / b_dense:.2f}"))

            def mixed_packed(Kx, Bx):
                Lx = block_cholesky_packed(Kx, index)
                return make_assembler(meta, cfg_p, mask)(Lx, Bx)

            t_mix_packed = time_fn(jax.jit(mixed_packed), K, Bt, reps=reps)
            rows.append((
                f"assembly/{tag}/mix_packed", t_mix_packed,
                f"speedup={t_mix_dense / t_mix_packed:.2f};"
                f"mem_ratio={b_packed / b_dense:.2f}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
