"""Paper Fig. 5 / Table 1: SC assembly time (and FLOP model) vs the
block-size hyperparameter, 2D and 3D, small and large subdomains.

Reproduces the paper's finding that a fixed block *size* (not count) is
the right parameterization and that the optimum is flat/insensitive once
blocks are big enough to keep level-3 kernels efficient.
"""
from __future__ import annotations

import jax

from repro.core import SchurAssemblyConfig, assembly_flops, make_assembler
from benchmarks.common import emit, subdomain_problem, time_fn


def run(sizes_2d=(16, 24), sizes_3d=(6, 9),
        block_sizes=(16, 32, 64, 128), reps: int = 3) -> list[tuple]:
    rows = []
    for dim, sizes in ((2, sizes_2d), (3, sizes_3d)):
        for e in sizes:
            for bs in block_sizes:
                prob = subdomain_problem(dim, e, bs)
                cfg = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs,
                                          storage="dense")
                fn = jax.jit(make_assembler(prob["meta"], cfg, prob["mask"]))
                us = time_fn(fn, jax.numpy.asarray(prob["L"]),
                             jax.numpy.asarray(prob["Bt"]), reps=reps)
                fl = assembly_flops(prob["meta"], cfg)["total"]
                rows.append((
                    f"blocksize/{dim}d/n{prob['n']}/bs{bs}", us,
                    f"flops={fl}",
                ))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
