"""Autotuned vs hand-picked vs dense-baseline SC assembly (ISSUE 1).

The paper picks the Table-1 variant and block size by hand per machine and
mesh; this bench shows the planner of :mod:`repro.core.autotune` recovering
(or beating) that choice automatically. Per problem it reports:

  * ``dense``     — ``schur_dense_baseline`` (the baseline of [9]),
  * ``hand``      — the architecture default (factor_split / input_split at
                    the problem's block size), the paper's hand choice,
  * ``autotuned`` — the plan chosen by ``plan_assembly(measure="auto")``.

Derived columns carry the plan string and the predicted-vs-measured model
error, i.e. how well the roofline cost model anticipated reality. The
autotuned row should never be slower than ``dense``: the measured search
pool always contains the dense-variant candidate (see docs/autotuning.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, subdomain_problem, time_fn
from repro.core import (
    SchurAssemblyConfig,
    build_stepped_meta,
    make_assembler,
    plan_assembly,
    schur_dense_baseline,
)


def run(sizes_2d=(16, 24), sizes_3d=(6,), bs: int = 32,
        reps: int = 5) -> list[tuple]:
    rows = []
    for dim, sizes in ((2, sizes_2d), (3, sizes_3d)):
        for e in sizes:
            prob = subdomain_problem(dim, e, bs)
            n, m = prob["n"], prob["m"]
            tag = f"autotune/{dim}d/n{n}"
            L = jnp.asarray(prob["L"])
            Bt = jnp.asarray(prob["Bt"])
            pat = prob["Bt"] != 0

            us_dense = time_fn(jax.jit(schur_dense_baseline), L, Bt,
                               reps=reps)
            rows.append((f"{tag}/dense", us_dense, "baseline-of-[9]"))

            hand = SchurAssemblyConfig(
                trsm_variant="factor_split", syrk_variant="input_split",
                block_size=bs, storage="dense")
            hand_fn = jax.jit(
                make_assembler(prob["meta"], hand, prob["mask"]))
            us_hand = time_fn(hand_fn, L, Bt, reps=reps)
            rows.append((f"{tag}/hand", us_hand,
                         f"speedup={us_dense / us_hand:.2f}x"))

            kpat = prob["K"] != 0
            p = plan_assembly(pat, factor_pattern=kpat,
                              measure="auto", cache=False)
            meta = build_stepped_meta(
                pat, block_size=p.cfg.block_size,
                rhs_block_size=p.cfg.rhs_bs)
            mask = None
            if p.cfg.prune:
                from repro.sparse import (
                    block_pattern,
                    block_symbolic_cholesky,
                )

                mask = block_symbolic_cholesky(
                    block_pattern(kpat, p.cfg.block_size))
            auto_fn = jax.jit(make_assembler(meta, p.cfg, mask))
            us_auto = time_fn(auto_fn, L, Bt, reps=reps)
            c = p.cfg
            pred_us = p.predicted_s * 1e6
            rows.append((
                f"{tag}/autotuned", us_auto,
                f"speedup={us_dense / us_auto:.2f}x "
                f"plan={c.trsm_variant}+{c.syrk_variant}@b{c.block_size}"
                f"{'+prune' if c.prune else ''}"
                f"{'+pallas' if c.use_pallas else ''} "
                f"predicted_us={pred_us:.1f}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
