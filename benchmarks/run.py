"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>] [--dry]

``--dry`` runs every module at smoke sizes with reps=1 — a CI-sized
end-to-end exercise of the whole bench surface (including the packed
storage rows), not a measurement.

Emits ``name,us_per_call,derived`` CSV rows. Mapping to the paper:
    bench_block_size  — Fig. 5 / Table 1 (block-size hyperparameter)
    bench_variants    — Fig. 6 (TRSM/SYRK splitting variants + pruning)
    bench_kernels     — Fig. 7 (pure-kernel speedups vs dense baseline)
    bench_assembly    — Fig. 8 (whole SC assembly, sep/mix)
    bench_autotune    — Table 1 made automatic (autotuned vs hand vs dense)
    bench_feti        — Figs. 9 & 10 (FETI preprocessing + amortization)
    bench_sharded     — distributed FETI scaling vs device count
    bench_lm          — assigned-architecture step smoke timings
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import HEADER

MODULES = [
    "bench_block_size",
    "bench_variants",
    "bench_kernels",
    "bench_assembly",
    "bench_autotune",
    "bench_feti",
    "bench_sharded",
    "bench_lm",
]

# smoke-sized kwargs for each module's run() under --dry: tiny problems,
# one rep — exercises every code path (incl. packed-vs-dense rows) fast
DRY_OVERRIDES = {
    "bench_block_size": dict(sizes_2d=(8,), sizes_3d=(4,),
                             block_sizes=(8, 16), reps=1),
    "bench_variants": dict(sizes_2d=(8,), sizes_3d=(4,), bs=8, reps=1),
    "bench_kernels": dict(sizes_2d=(8,), sizes_3d=(4,), bs=8, reps=1),
    "bench_assembly": dict(sizes_2d=(8,), sizes_3d=(4,), ela_2d=(6,),
                           ela_3d=(3,), bs=8, reps=1,
                           stage_graph_cases=((2, (2, 2), (3, 3)),)),
    "bench_autotune": dict(sizes_2d=(8,), sizes_3d=(4,), bs=8, reps=1),
    "bench_feti": dict(cases=(("heat", 2, (2, 2), (4, 4)),
                              ("elasticity", 2, (2, 2), (3, 3))),
                       bs=8, reps=1, n_rhs_list=(1, 2)),
    "bench_sharded": dict(dim=2, sub_grid=(2, 2), elems_per_sub=(4, 4),
                          bs=8, reps=1),
    "bench_lm": dict(reps=1),
}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default=None,
                   help="run a single bench module by name")
    p.add_argument("--dry", action="store_true",
                   help="smoke sizes + reps=1: exercise every bench path "
                        "quickly (CI), numbers are not measurements")
    args = p.parse_args(argv)

    print(HEADER)
    t0 = time.perf_counter()
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t1 = time.perf_counter()
        if args.dry:
            from benchmarks.common import emit

            emit(mod.run(**DRY_OVERRIDES.get(name, {})))
        else:
            mod.main()
        print(f"# {name}: {time.perf_counter() - t1:.1f}s", file=sys.stderr)
    print(f"# total: {time.perf_counter() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
