"""Paper Fig. 6: comparison of the TRSM splitting variants (RHS vs factor,
with/without pruning) and the SYRK variants (input vs output splitting),
across subdomain sizes. Reports wall time and the FLOP model per variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    SchurAssemblyConfig,
    syrk_dense,
    syrk_input_split,
    syrk_output_split,
    trsm_dense,
    trsm_factor_split,
    trsm_rhs_split,
)
from benchmarks.common import emit, subdomain_problem, time_fn


def run(sizes_2d=(16, 24), sizes_3d=(6, 9), bs: int = 32,
        reps: int = 3) -> list[tuple]:
    rows = []
    for dim, sizes in ((2, sizes_2d), (3, sizes_3d)):
        for e in sizes:
            prob = subdomain_problem(dim, e, bs)
            L = jnp.asarray(prob["L"])
            Bp = jnp.asarray(prob["Bt"][:, prob["meta"].perm])
            meta, mask = prob["meta"], prob["mask"]
            tag = f"{dim}d/n{prob['n']}"

            trsm_variants = {
                "trsm_dense": jax.jit(trsm_dense),
                "trsm_rhs": jax.jit(lambda lo, b: trsm_rhs_split(lo, b, meta)),
                "trsm_factor": jax.jit(
                    lambda lo, b: trsm_factor_split(lo, b, meta)
                ),
                "trsm_factor_prune": jax.jit(
                    lambda lo, b: trsm_factor_split(lo, b, meta, block_mask=mask)
                ),
            }
            flops = {
                "trsm_dense": meta.flops_trsm_dense(),
                "trsm_rhs": meta.flops_trsm_rhs_split(),
                "trsm_factor": meta.flops_trsm_factor_split(),
                "trsm_factor_prune": meta.flops_trsm_factor_split(),
            }
            for name, fn in trsm_variants.items():
                us = time_fn(fn, L, Bp, reps=reps)
                rows.append((f"variants/{tag}/{name}", us,
                             f"flops={flops[name]}"))

            Y = trsm_dense(L, Bp)
            syrk_variants = {
                "syrk_dense": jax.jit(syrk_dense),
                "syrk_input": jax.jit(lambda y: syrk_input_split(y, meta)),
                "syrk_output": jax.jit(lambda y: syrk_output_split(y, meta)),
            }
            sflops = {
                "syrk_dense": meta.flops_syrk_dense(),
                "syrk_input": meta.flops_syrk_input_split(),
                "syrk_output": meta.flops_syrk_output_split(),
            }
            for name, fn in syrk_variants.items():
                us = time_fn(fn, Y, reps=reps)
                rows.append((f"variants/{tag}/{name}", us,
                             f"flops={sflops[name]}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
