"""Paper Fig. 7: pure TRSM and SYRK kernel speedup of the sparsity-
utilizing variants over the dense baseline, across subdomain sizes.

Two speedup columns per row:
  * measured (CPU wall time, relative),
  * FLOP-model (transfers to the TPU target; the paper's theoretical
    ceiling for a perfect triangle is 3.0 for both kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    syrk_dense,
    syrk_input_split,
    trsm_dense,
    trsm_factor_split,
)
from benchmarks.common import emit, subdomain_problem, time_fn


def run(sizes_2d=(12, 16, 24, 32), sizes_3d=(5, 7, 9, 11), bs: int = 32,
        reps: int = 3) -> list[tuple]:
    rows = []
    for dim, sizes in ((2, sizes_2d), (3, sizes_3d)):
        for e in sizes:
            prob = subdomain_problem(dim, e, bs)
            L = jnp.asarray(prob["L"])
            Bp = jnp.asarray(prob["Bt"][:, prob["meta"].perm])
            meta, mask = prob["meta"], prob["mask"]
            tag = f"{dim}d/n{prob['n']}/m{prob['m']}"

            t_dense = time_fn(jax.jit(trsm_dense), L, Bp, reps=reps)
            t_opt = time_fn(
                jax.jit(lambda lo, b: trsm_factor_split(lo, b, meta,
                                                        block_mask=mask)),
                L, Bp, reps=reps,
            )
            fl_speed = meta.flops_trsm_dense() / max(
                meta.flops_trsm_factor_split(), 1
            )
            rows.append((f"kernels/{tag}/trsm", t_opt,
                         f"speedup_measured={t_dense / t_opt:.2f}"
                         f";speedup_flops={fl_speed:.2f}"))

            Y = trsm_dense(L, Bp)
            s_dense = time_fn(jax.jit(syrk_dense), Y, reps=reps)
            s_opt = time_fn(jax.jit(lambda y: syrk_input_split(y, meta)), Y,
                            reps=reps)
            sfl = meta.flops_syrk_dense() / max(
                meta.flops_syrk_input_split(), 1
            )
            rows.append((f"kernels/{tag}/syrk", s_opt,
                         f"speedup_measured={s_dense / s_opt:.2f}"
                         f";speedup_flops={sfl:.2f}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
