"""Distributed FETI scaling: assembly + per-iteration time vs device count.

Shards the subdomain axis of one cluster over ``("data",)`` meshes of
1, 2, 4, ... devices (:mod:`repro.feti.sharded`) and measures

  * ``preproc``  — compiled numerical factorization + explicit SC assembly
    (the paper's preprocessing stage, now partitioned per-device), and
  * ``iter_explicit`` / ``iter_implicit`` — one dual-operator application
    under shard_map (a device-local GEMV/TRSV batch + one λ-sized psum).

On this CPU container the devices are XLA host-platform devices forced via
``--xla_force_host_platform_device_count`` (set REPRO_BENCH_DEVICES before
running to change the pool, default 8), so the numbers measure *scaling
shape* and exchange overhead, not real accelerator throughput.
"""
from __future__ import annotations

import os
import sys

from repro.launch.mesh import force_host_device_count

# must be set before the jax backend initializes (import side effect)
_N_DEV = int(os.environ.get("REPRO_BENCH_DEVICES", "8"))
force_host_device_count(_N_DEV)

import jax
import jax.numpy as jnp

from benchmarks.common import emit, fmt_bytes, time_fn
from repro.core import SchurAssemblyConfig
from repro.fem import decompose_heat_problem
from repro.feti import FetiConfig
from repro.feti import sharded as shlib
from repro.feti.assembly import preprocess_cluster
from repro.launch.mesh import make_feti_mesh
from repro.sparse import PackedBlocks


def run(dim: int = 2, sub_grid=(4, 4), elems_per_sub=(16, 16),
        bs: int = 16, reps: int = 3) -> list[tuple]:
    if len(jax.devices()) < _N_DEV:
        # e.g. under `python -m benchmarks.run`, where an earlier bench
        # module already initialized the backend at its device count
        print(
            f"[bench_sharded] backend has {len(jax.devices())} device(s), "
            f"wanted {_N_DEV} — jax initialized before this module? "
            f"(run with `--only sharded` for the full scaling curve)",
            file=sys.stderr,
        )
    prob = decompose_heat_problem(dim, sub_grid, elems_per_sub)
    cfg = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs)
    nl = prob.n_lambda
    S = prob.n_subdomains
    n = prob.subdomains[0].n
    tag = f"{dim}d/S{S}/n{n}"

    counts = []
    d = 1
    while d <= len(jax.devices()):
        counts.append(d)
        d *= 2

    rows = []
    base_preproc = base_expl = base_impl = None
    for nd in counts:
        mesh = make_feti_mesh(nd)
        st = preprocess_cluster(prob, FetiConfig(schur=cfg, mesh=mesh))

        # preprocessing: re-run the compiled factorize+assemble the state
        # carries on already-placed stacks (multi-step regime, fixed pattern)
        L_d = st.L.unpack() if isinstance(st.L, PackedBlocks) else st.L
        Kp = L_d @ jnp.swapaxes(L_d, -1, -2)  # any SPD stack, placed right
        t_pre = time_fn(lambda a, b: st.prep(a, b)[1], Kp, st.Btp, reps=reps)

        lam = jax.device_put(jnp.zeros((nl,)), shlib.replicated_sharding(mesh))
        expl = jax.jit(lambda p, st=st, mesh=mesh: shlib.explicit_dual_apply(
            mesh, st.F, st.lambda_ids, nl, p))
        impl = jax.jit(lambda p, st=st, mesh=mesh: shlib.implicit_dual_apply(
            mesh, st.L, st.Btp, st.lambda_ids, nl, p))
        t_expl = time_fn(expl, lam, reps=reps)
        t_impl = time_fn(impl, lam, reps=reps)

        if nd == 1:
            base_preproc, base_expl, base_impl = t_pre, t_expl, t_impl
        rows.append((f"feti_sharded/{tag}/d{nd}/preproc", t_pre,
                     f"speedup_vs_1dev={base_preproc / t_pre:.2f};"
                     + fmt_bytes(st)))
        rows.append((f"feti_sharded/{tag}/d{nd}/iter_explicit", t_expl,
                     f"speedup_vs_1dev={base_expl / t_expl:.2f}"))
        rows.append((f"feti_sharded/{tag}/d{nd}/iter_implicit", t_impl,
                     f"speedup_vs_1dev={base_impl / t_impl:.2f}"))
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
