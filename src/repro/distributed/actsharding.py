"""Activation sharding constraints for model internals.

GSPMD propagates well through plain matmul chains but loses the batch
sharding across the transpose/reshape pipelines inside the recurrent
kernels (rwkv chunking, moe dispatch) — without these constraints the
dry-run showed 45 GiB/device of replicated fp32 temporaries on a 1.6B
model. The model code calls :func:`shard_act` at the few points that
matter; outside a mesh context it is a no-op, so single-device tests and
CPU smoke runs are untouched.

Specs are divisibility-guarded like everything in sharding.py: an axis
that doesn't divide degrades to replication rather than erroring.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_sharding", "shard_act", "current_mesh"]

_MESH: Optional[Mesh] = None
_SP: bool = False


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh], sp: bool = True):
    """Enable activation constraints for code traced within.

    ``sp``: Megatron-style sequence parallelism — the literal axis name
    "sp" in shard_act calls resolves to 'model', sharding inter-block
    activations along the sequence. XLA inserts the all-gather at each
    block's attention/MLP entry and the reduce-scatter at its exit; the
    per-layer residual memory drops by the TP width.
    """
    global _MESH, _SP
    prev, prev_sp = _MESH, _SP
    _MESH, _SP = mesh, sp
    try:
        yield
    finally:
        _MESH, _SP = prev, prev_sp


def current_mesh() -> Optional[Mesh]:
    return _MESH


def _axis_ok(mesh: Mesh, dim: int, name) -> bool:
    if isinstance(name, tuple):
        size = 1
        for n in name:
            size *= mesh.shape.get(n, 1)
    else:
        size = mesh.shape.get(name, 1)
    return dim % size == 0


def shard_act(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x`` to PartitionSpec(*axes) on the active mesh.

    ``axes`` entries: mesh axis name, tuple of names, or None; 'dp' expands
    to the data-parallel axes present in the mesh (('pod','data')).
    """
    mesh = _MESH
    if mesh is None:
        return x
    spec = []
    for i, a in enumerate(axes):
        if a == "dp":
            a = tuple(n for n in ("pod", "data") if n in mesh.shape) or None
        elif a == "sp":
            a = "model" if _SP else None
        if a is not None and not _axis_ok(mesh, x.shape[i], a):
            a = None
        spec.append(a)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
