"""Sharding rules: map every parameter / batch / cache tensor to a
PartitionSpec on the production mesh.

Strategy (DESIGN.md §6):
  * batch axis            -> ('pod', 'data')   (pure DP across pods)
  * params, dim "in"      -> 'data'            (FSDP / ZeRO-3 via GSPMD:
                                                XLA inserts per-layer
                                                all-gathers)
  * params, dim "out/TP"  -> 'model'           (tensor parallelism: heads,
                                                ffn hidden, vocab)
  * MoE expert axis       -> 'model' when divisible (EP), else TP fallback
  * decode KV cache seq   -> 'model'           (flash-decoding style)

Every axis assignment is divisibility-guarded: a dimension that does not
divide the mesh axis silently degrades to replication on that axis, so one
rule set serves all 10 architectures (e.g. grok's 8 experts vs deepseek's
160).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "batch_spec",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "opt_state_shardings",
    "axis_size",
]


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def _fit(mesh: Mesh, dim: int, name) -> Optional[str]:
    """Axis name if the dim divides the axis size, else None (replicate)."""
    if name is None:
        return None
    return name if dim % axis_size(mesh, name) == 0 else None


def batch_spec(mesh: Mesh) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(dp if dp else None)


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _matrix_spec(mesh: Mesh, shape, tp_dim: int, fsdp_dim: int,
                 extra_leading: int = 0) -> P:
    """Generic 2D weight spec with optional leading stacked axes."""
    axes = [None] * len(shape)
    axes[tp_dim] = _fit(mesh, shape[tp_dim], "model")
    axes[fsdp_dim] = _fit(mesh, shape[fsdp_dim], "data")
    return P(*axes)


def _spec_for_param(mesh: Mesh, path: str, x) -> P:
    shape = x.shape
    nd = len(shape)

    def mat(tp_last: bool) -> P:
        axes = [None] * nd
        if nd >= 2:
            tp_dim = nd - 1 if tp_last else nd - 2
            fs_dim = nd - 2 if tp_last else nd - 1
            axes[tp_dim] = _fit(mesh, shape[tp_dim], "model")
            axes[fs_dim] = _fit(mesh, shape[fs_dim], "data")
        return P(*axes)

    if "embed" in path or "lm_head" in path:
        # (V, d) / (d, V): vocab-parallel + FSDP
        vdim = 0 if "embed" in path and "lm_head" not in path else nd - 1
        axes = [None] * nd
        axes[vdim] = _fit(mesh, shape[vdim], "model")
        other = nd - 1 - vdim
        axes[other] = _fit(mesh, shape[other], "data")
        return P(*axes)

    if "router" in path:
        return P(*([None] * (nd - 1) + [_fit(mesh, shape[-1], "model")]))

    # stacked expert weights (…, E, d, ff) / (…, E, ff, d): EP over 'model'.
    # MoE weights sit directly under "mlp/" as raw arrays (no "/w" suffix),
    # which distinguishes them from scan-stacked dense MLP weights.
    if path.endswith(("mlp/wi", "mlp/wg", "mlp/wo")) and nd >= 3:
        e_ax = _fit(mesh, shape[-3], "model")
        axes = [None] * nd
        axes[-3] = e_ax
        if e_ax is None:
            # EP impossible (e.g. grok's 8 experts on a 16-wide axis):
            # fall back to TP on the ff dim + FSDP on the d dim.
            hid = nd - 2 if path.endswith("wo") else nd - 1  # ff dim
            oth = nd - 1 if path.endswith("wo") else nd - 2  # d dim
            axes[hid] = _fit(mesh, shape[hid], "model")
            axes[oth] = _fit(mesh, shape[oth], "data")
        else:
            axes[-2] = _fit(mesh, shape[-2], "data")
        return P(*axes)

    # projections whose OUTPUT is the TP dim
    if any(k in path for k in ("wq", "wk", "wv", "wg", "wi", "wq_b", "wk_b",
                               "wv_b", "w_in", "w_gate_in", "cm_k", "wa",
                               "wx", "wr")):
        if nd >= 2:
            return mat(tp_last=True)
        return P(_fit(mesh, shape[-1], "model"))

    # projections whose INPUT is the TP dim
    if any(k in path for k in ("wo", "w_out", "cm_v", "cm_r")):
        if nd >= 2:
            return mat(tp_last=False)
        return P(None)

    # everything else (norm scales, biases, gates, decay params): replicate
    return P(*([None] * nd))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(mesh: Mesh, params, fsdp: bool = True) -> object:
    """NamedSharding tree matching ``params``.

    ``fsdp=False`` replicates over the 'data' axis (pure TP): the decode
    configuration for models whose TP-sharded weights fit HBM — per-step
    ZeRO weight regathers are pure overhead in the memory-bound decode
    regime (§Perf: recurrentgemma decode collective fix)."""

    def one(path, x):
        spec = _spec_for_param(mesh, _path_str(path), x)
        if not fsdp:
            spec = P(*[
                None if a == "data"
                or (isinstance(a, tuple) and "data" in a) else a
                for a in spec
            ])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(mesh: Mesh, batch) -> object:
    bs = batch_spec(mesh)

    def spec(x):
        # divisibility-guarded: long_500k has global_batch=1, which rides
        # replicated (its parallelism lives in the model/cache axes)
        first = _fit(mesh, x.shape[0], bs[0]) if bs and len(x.shape) else None
        axes = [first] + [None] * (len(x.shape) - 1)
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(spec, batch)


def cache_shardings(mesh: Mesh, cache, min_seq_to_shard: int = 0) -> object:
    """KV caches: batch -> DP axes, sequence axis -> 'model'
    (flash-decoding: every model shard owns a slice of the history).
    Recurrent states (rwkv S / rglru h / conv) shard batch + head/width.

    ``min_seq_to_shard``: sequence axes shorter than this replicate over
    'model' instead — seq-sharding a 2048-slot ring cache only buys
    per-step gathers (§Perf: recurrentgemma decode collective fix)."""
    dp = _dp_axes(mesh)

    def spec(path, x):
        pstr = _path_str(path)
        nd = len(x.shape)
        axes = [None] * nd
        b_ax = 1 if "body" in pstr else 0  # scan-stacked: (cycles, B, ...)
        if nd > b_ax:
            axes[b_ax] = _fit(mesh, x.shape[b_ax], dp if dp else None)
        leaf = pstr.rsplit("/", 1)[-1]
        if leaf in ("k", "v", "ckv", "krope", "pos") and nd > b_ax + 1:
            if x.shape[b_ax + 1] >= min_seq_to_shard:
                axes[b_ax + 1] = _fit(mesh, x.shape[b_ax + 1], "model")
        elif leaf in ("S", "h", "conv") and nd > b_ax + 1:
            axes[b_ax + 1] = _fit(mesh, x.shape[b_ax + 1], "model")
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(spec, cache)


def opt_state_shardings(mesh: Mesh, opt_state, params_sh) -> object:
    return {
        "m": params_sh,
        "v": params_sh,
        "step": NamedSharding(mesh, P()),
    }
