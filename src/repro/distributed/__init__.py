"""Distribution substrate: sharding rules (DP/FSDP/TP/EP/SP), elastic
sharded checkpointing, straggler monitoring, gradient compression."""
from repro.distributed.checkpoint import (
    available_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.distributed.compression import bf16_compress, make_int8_error_feedback
from repro.distributed.elastic import ElasticPlan, StepTimer, StragglerMonitor
from repro.distributed.sharding import (
    batch_shardings,
    batch_spec,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
)

__all__ = [
    "ElasticPlan",
    "StepTimer",
    "StragglerMonitor",
    "available_steps",
    "batch_shardings",
    "batch_spec",
    "bf16_compress",
    "cache_shardings",
    "latest_step",
    "make_int8_error_feedback",
    "opt_state_shardings",
    "param_shardings",
    "restore_checkpoint",
    "save_checkpoint",
]
