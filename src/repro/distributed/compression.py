"""Gradient compression for cross-pod reductions.

On a multi-pod mesh the 'pod' axis rides DCI links (~an order of magnitude
slower than ICI); compressing gradients before the cross-pod reduce is the
standard lever. Two schemes:

  * bf16 cast (2x) — what the train step applies by default across pods;
    numerically safe with fp32 Adam moments.
  * int8 per-tensor scale (4x) with error feedback — the residual of the
    quantizer is carried and re-added next step, which keeps SGD unbiased
    in the long run.

Compression is wired in via TrainConfig.grad_transform; the error-feedback
state rides inside the returned closure's ``state`` pytree.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["bf16_compress", "make_int8_error_feedback"]


def bf16_compress(grads):
    """Simulate a bf16 all-reduce: cast down, cast back."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads
    )


def _int8_roundtrip(g: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(g.dtype) * scale


def make_int8_error_feedback(params_template
                             ) -> Tuple[Callable, dict]:
    """Returns (transform(grads, state) -> (grads, state), initial_state)."""
    state0 = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params_template
    )

    def transform(grads, state):
        new_grads = jax.tree.map(
            lambda g, e: _int8_roundtrip(g.astype(jnp.float32) + e).astype(
                g.dtype
            ),
            grads, state,
        )
        new_state = jax.tree.map(
            lambda g, e, q: g.astype(jnp.float32) + e - q.astype(jnp.float32),
            grads, state, new_grads,
        )
        return new_grads, new_state

    return transform, state0
