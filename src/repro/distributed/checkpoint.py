"""Sharded, atomic, elastic checkpointing.

Layout per step:  <dir>/step_<N>/
    manifest.json   — step, flat key list, shapes/dtypes, mesh shape
    arrays.npz      — one entry per flattened pytree leaf

Properties needed at 1000-node scale, scaled down to this container:
  * atomic publish (write to tmp dir + rename) — a failed node never leaves
    a half-written checkpoint visible;
  * keep-last-k garbage collection;
  * ELASTIC restore: leaves are stored logically (unsharded); restore takes
    the *current* mesh + sharding tree and device_puts each leaf into its
    new layout, so a job can come back on a different pod count;
  * fully addressable leaves are gathered via jax.device_get before save
    (multi-host would gather per-shard files; the manifest format already
    records the mesh for that extension).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "available_steps"]

_SEP = "::"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(jax.device_get(leaf))
        # npz cannot represent ml_dtypes (bfloat16/fp8): store widened;
        # restore casts back to the template leaf dtype (exact for bf16).
        if arr.dtype.name in ("bfloat16",) or arr.dtype.name.startswith("float8"):
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    keep: int = 3, extra: Optional[dict] = None) -> str:
    """Atomically write ``tree`` for ``step``; prune to the newest ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    # GC
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"),
                      ignore_errors=True)
    return final


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, template: Any, step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template``.

    ``shardings``: optional matching tree of NamedShardings — the elastic
    path: leaves are device_put into the *current* mesh layout regardless
    of the mesh they were saved from.
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    data = np.load(os.path.join(path, "arrays.npz"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(paths))
    leaves = []
    for (kpath, leaf), sh in zip(paths, sh_leaves):
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in kpath
        )
        arr = np.asarray(data[key])
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jax.numpy.asarray(arr).astype(leaf.dtype)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
