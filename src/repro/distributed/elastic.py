"""Fault tolerance at the training-loop level: straggler detection, step
retry bookkeeping, and elastic resume decisions.

At 1000+ nodes the failure model is: (a) hosts die (handled by checkpoint/
restart — see checkpoint.py), (b) hosts straggle (handled here: per-step
wall-time tracking flags outliers so the scheduler can replace them or the
launcher can drop to a smaller mesh via the elastic restore path)."""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

__all__ = ["StragglerMonitor", "StepTimer", "ElasticPlan"]


class StragglerMonitor:
    """Tracks per-host step durations, flags hosts whose rolling median
    exceeds ``threshold`` x the fleet median."""

    def __init__(self, num_hosts: int, window: int = 16,
                 threshold: float = 1.5):
        self.num_hosts = num_hosts
        self.window = window
        self.threshold = threshold
        self._hist = [deque(maxlen=window) for _ in range(num_hosts)]

    def record(self, host: int, duration_s: float) -> None:
        self._hist[host].append(duration_s)

    @staticmethod
    def _median(xs) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def fleet_median(self) -> Optional[float]:
        per_host = [self._median(h) for h in self._hist if h]
        return self._median(per_host) if per_host else None

    def stragglers(self) -> list[int]:
        fleet = self.fleet_median()
        if fleet is None or fleet <= 0:
            return []
        return [
            i for i, h in enumerate(self._hist)
            if h and self._median(h) > self.threshold * fleet
        ]

    def healthy_hosts(self) -> int:
        return self.num_hosts - len(self.stragglers())


class StepTimer:
    """Context-manager step timer feeding the monitor (host 0 locally)."""

    def __init__(self, monitor: StragglerMonitor, host: int = 0):
        self.monitor = monitor
        self.host = host
        self.last: Optional[float] = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.last = time.perf_counter() - self._t0
        self.monitor.record(self.host, self.last)
        return False


@dataclasses.dataclass
class ElasticPlan:
    """Decide the mesh for a restart given surviving hosts.

    Data-parallel ranks come in pod-sized groups; we keep the 'model' axis
    intact (TP topology is fixed by ICI wiring) and shrink the DP axes to
    the largest power-of-two of surviving groups — the checkpoint restore
    re-shards parameters onto the new mesh (checkpoint.restore_checkpoint).
    """

    total_hosts: int
    hosts_per_pod: int

    def plan(self, surviving_hosts: int) -> dict:
        pods = max(surviving_hosts // self.hosts_per_pod, 1)
        # largest power of two <= pods
        p2 = 1
        while p2 * 2 <= pods:
            p2 *= 2
        return {
            "pods": p2,
            "dropped_hosts": self.total_hosts - p2 * self.hosts_per_pod,
            "global_batch_scale": p2 * self.hosts_per_pod / self.total_hosts,
        }
