"""Symbolic block factorization (host-side, paper §2.2's symbolic stage).

Maps the scalar sparsity pattern of the (permuted) subdomain matrix onto a
uniform block grid and runs symbolic elimination at block granularity,
producing the lower-triangular *block fill mask* of the Cholesky factor.

The mask drives (a) the block-sparse numerical Cholesky (cholesky.py),
(b) the pruning of factor-split TRSM updates (core/trsm.py), and
(c) the FLOP model used by the benchmarks. This is the TPU-native analogue
of CSR symbolic factorization: zero/nonzero is decided per MXU-sized tile,
not per scalar.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "matrix_pattern_from_elems",
    "block_pattern",
    "block_symbolic_cholesky",
]


def matrix_pattern_from_elems(n: int, elems: np.ndarray) -> np.ndarray:
    """Dense boolean pattern of the assembled FEM matrix (host-side)."""
    pat = np.zeros((n, n), dtype=bool)
    elems = np.asarray(elems)
    for v in range(elems.shape[1]):
        for w in range(elems.shape[1]):
            pat[elems[:, v], elems[:, w]] = True
    return pat


def block_pattern(pattern: np.ndarray, block_size: int) -> np.ndarray:
    """Reduce a scalar (n, n) pattern to a (nb, nb) block pattern."""
    n = pattern.shape[0]
    nb = -(-n // block_size)
    pad = nb * block_size - n
    if pad:
        pattern = np.pad(pattern, ((0, pad), (0, pad)))
    blocked = pattern.reshape(nb, block_size, nb, block_size)
    return blocked.any(axis=(1, 3))


def block_symbolic_cholesky(bpat: np.ndarray) -> np.ndarray:
    """Symbolic elimination at block level: returns the lower-triangular
    block fill mask of L (True = structurally nonzero block).

    Standard fill rule: eliminating block column k connects every pair of
    blocks below it — ``mask[i, j] |= mask[i, k] & mask[j, k]`` for i>=j>k.
    """
    nb = bpat.shape[0]
    mask = np.tril(bpat | bpat.T)
    for k in range(nb):
        below = np.flatnonzero(mask[k + 1 :, k]) + k + 1
        if below.size:
            # vectorized pairwise fill
            mask[np.ix_(below, below)] |= True
    return np.tril(mask)


def block_fill_stats(mask: np.ndarray) -> dict:
    """Density of the factor's block fill (benchmark/roofline helper)."""
    nb = mask.shape[0]
    total = nb * (nb + 1) // 2
    nnz = int(np.tril(mask).sum())
    return {"nb": nb, "nnz_blocks": nnz, "total_blocks": total,
            "density": nnz / max(total, 1)}
