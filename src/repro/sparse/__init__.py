"""Sparse direct-solver substrate: fill-reducing ordering (geometric nested
dissection — the structured-grid analogue of the paper's Metis), symbolic
block factorization (block elimination tree / fill mask), and the blocked
numerical Cholesky in JAX whose tiles are born MXU-aligned."""
from repro.sparse.cholesky import block_cholesky, block_cholesky_flops
from repro.sparse.ordering import (
    nested_dissection_order,
    node_ordering,
    rcm_order,
)
from repro.sparse.packed import (
    PackedBlockIndex,
    PackedBlocks,
    block_cholesky_packed,
    pack_factor,
    packed_block_index_for,
    packed_symm_matvec,
    packed_tri_solve,
)
from repro.sparse.symbolic import (
    block_pattern,
    block_symbolic_cholesky,
    matrix_pattern_from_elems,
)

__all__ = [
    "PackedBlockIndex",
    "PackedBlocks",
    "block_cholesky",
    "block_cholesky_flops",
    "block_cholesky_packed",
    "block_pattern",
    "block_symbolic_cholesky",
    "matrix_pattern_from_elems",
    "nested_dissection_order",
    "node_ordering",
    "pack_factor",
    "packed_block_index_for",
    "packed_symm_matvec",
    "packed_tri_solve",
    "rcm_order",
]
