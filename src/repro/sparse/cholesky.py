"""Blocked numerical Cholesky in JAX (paper §2.2's numerical stage).

Right-looking block Cholesky over a uniform block grid. With a block fill
mask from the symbolic stage, structurally-zero blocks are skipped — the
TPU-native analogue of sparse supernodal factorization: every surviving
block is a dense MXU-aligned tile.

Block loops are Python loops over compile-time-constant indices (the mask
is static per decomposition), so XLA sees a static program; multi-step
simulations with fixed sparsity recompile zero times, matching the paper's
symbolic/numeric split.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["block_cholesky", "block_cholesky_flops"]


def _solve_lower_right(Lkk: jax.Array, W: jax.Array) -> jax.Array:
    """Solve X Lkkᵀ = W for X (i.e. X = W Lkk⁻ᵀ)."""
    return jax.lax.linalg.triangular_solve(
        Lkk, W, left_side=False, lower=True, transpose_a=True
    )


def block_cholesky(
    K: jax.Array,
    block_size: int,
    mask: Optional[np.ndarray] = None,
) -> jax.Array:
    """Cholesky factor L (lower, dense storage) of SPD K.

    Args:
      K: (n, n) SPD matrix.
      block_size: tile size (128-aligned on real TPU; small in tests).
      mask: optional (nb, nb) lower-triangular block fill mask from
        :func:`repro.sparse.symbolic.block_symbolic_cholesky`. Blocks
        outside the mask are skipped entirely (their result is zero).
    """
    n = K.shape[0]
    nb = -(-n // block_size)

    def blk(k):
        return k * block_size, min((k + 1) * block_size, n)

    if mask is not None:
        mask = np.asarray(mask)
        if mask.shape != (nb, nb):
            raise ValueError(f"mask shape {mask.shape} != ({nb},{nb})")

    W = K
    L = jnp.zeros_like(K)
    for k in range(nb):
        k0, k1 = blk(k)
        Lkk = jnp.linalg.cholesky(W[k0:k1, k0:k1])
        L = L.at[k0:k1, k0:k1].set(Lkk)
        if k1 >= n:
            break
        if mask is None:
            panel = _solve_lower_right(Lkk, W[k1:, k0:k1])
            L = L.at[k1:, k0:k1].set(panel)
            W = W.at[k1:, k1:].add(-(panel @ panel.T))
        else:
            below = [i for i in range(k + 1, nb) if mask[i, k]]
            panels = {}
            for i in below:
                i0, i1 = blk(i)
                Lik = _solve_lower_right(Lkk, W[i0:i1, k0:k1])
                L = L.at[i0:i1, k0:k1].set(Lik)
                panels[i] = (i0, i1, Lik)
            for i in below:
                i0, i1, Lik = panels[i]
                for j in below:
                    if j > i:
                        break
                    j0, j1, Ljk = panels[j]
                    W = W.at[i0:i1, j0:j1].add(-(Lik @ Ljk.T))
    return L


def block_cholesky_flops(n: int, block_size: int,
                         mask: Optional[np.ndarray] = None) -> int:
    """FLOP model of the blocked factorization (MAC = 2 flops)."""
    nb = -(-n // block_size)

    def bsz(k):
        return min((k + 1) * block_size, n) - k * block_size

    total = 0
    for k in range(nb):
        b = bsz(k)
        total += b * b * b // 3  # dense Cholesky of the diagonal block
        below = (
            [i for i in range(k + 1, nb) if mask[i, k]]
            if mask is not None
            else list(range(k + 1, nb))
        )
        for i in below:
            total += bsz(i) * b * b  # panel triangular solve
        for ii, i in enumerate(below):
            for j in below[: ii + 1]:
                total += 2 * bsz(i) * bsz(j) * b  # trailing GEMM update
    return total
