"""Fill-reducing orderings (host-side, symbolic phase — paper §2.2).

The paper uses Metis inside PARDISO/CHOLMOD. Our subdomains are structured
boxes, so we use *geometric nested dissection*, which is exactly what Metis
converges to on such grids and gives the two properties the paper's
technique relies on:

  * low fill in L with large zero off-diagonal blocks (block skipping), and
  * approximately uniformly distributed column pivots of B̃ᵀ after the
    permutation (the surface DOFs carrying B's nonzeros end up spread over
    the elimination order), which is what makes the stepped shape useful.

An RCM (bandwidth-minimizing) ordering is provided as an alternative; it
concentrates fill near the diagonal (good for the banded block mask) but
pushes all surface DOFs of one face together, so the stepped shape is
coarser. The benchmark harness compares both.
"""
from __future__ import annotations

import numpy as np

__all__ = ["nested_dissection_order", "rcm_order", "node_ordering"]


def node_ordering(node_shape: tuple[int, ...], ordering: str) -> np.ndarray:
    """Dispatch a named fill-reducing node ordering ("nd" | "rcm" |
    "natural") for a structured node grid — the one mapping shared by the
    cluster preprocessor and the dirichlet boundary/interior split, so
    adding an ordering cannot silently diverge between them."""
    if ordering == "nd":
        return nested_dissection_order(node_shape)
    if ordering == "rcm":
        return rcm_order(node_shape)
    if ordering == "natural":
        return np.arange(int(np.prod(node_shape)), dtype=np.int64)
    raise ValueError(f"unknown ordering {ordering!r}")


def nested_dissection_order(node_shape: tuple[int, ...], leaf: int = 4) -> np.ndarray:
    """Geometric nested dissection of a structured node grid.

    Args:
      node_shape: nodes per axis, e.g. (9, 9) for an 8x8-element subdomain.
      leaf: boxes with every side <= leaf are emitted without further
        dissection.

    Returns:
      perm (n,) int64 such that ``K[perm][:, perm]`` has ND structure; i.e.
      ``perm[k]`` = original (Fortran-order) node id eliminated k-th.
    """
    dim = len(node_shape)
    strides = [1]
    for d in range(dim - 1):
        strides.append(strides[-1] * node_shape[d])
    strides_arr = np.asarray(strides)

    out: list[np.ndarray] = []

    def emit(box):
        ranges = [np.arange(lo, hi) for lo, hi in box]
        grid = np.meshgrid(*ranges, indexing="ij")
        ids = sum(g.ravel(order="F") * s for g, s in zip(grid, strides_arr))
        out.append(np.sort(ids))

    def dissect(box):
        sizes = [hi - lo for lo, hi in box]
        if max(sizes) <= leaf:
            emit(box)
            return
        ax = int(np.argmax(sizes))
        lo, hi = box[ax]
        mid = (lo + hi) // 2
        left = list(box)
        left[ax] = (lo, mid)
        right = list(box)
        right[ax] = (mid + 1, hi)
        sep = list(box)
        sep[ax] = (mid, mid + 1)
        dissect(left)
        if mid + 1 < hi:
            dissect(right)
        emit(sep)

    dissect([(0, s) for s in node_shape])
    perm = np.concatenate(out).astype(np.int64)
    n = int(np.prod(node_shape))
    assert perm.shape == (n,) and len(np.unique(perm)) == n
    return perm


def rcm_order(node_shape: tuple[int, ...]) -> np.ndarray:
    """Reverse Cuthill–McKee on the structured grid graph (via lexicographic
    anti-diagonal sweep, which is the exact RCM result for box grids)."""
    dim = len(node_shape)
    ranges = [np.arange(s) for s in node_shape]
    grid = np.meshgrid(*ranges, indexing="ij")
    idx = np.stack([g.ravel(order="F") for g in grid], axis=1)  # (n, dim)
    level = idx.sum(axis=1)  # BFS level from corner
    order = np.lexsort(tuple(idx[:, d] for d in range(dim)) + (level,))
    return order[::-1].astype(np.int64).copy()
