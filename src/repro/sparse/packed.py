"""Packed block-sparse factor storage: the symbolic fill mask AS the layout.

The symbolic stage (symbolic.py) produces the block fill mask of the
Cholesky factor. Everywhere else in the pipeline that mask used to be a
*FLOP filter* — structurally-zero blocks were skipped, but every factor was
still materialized as a dense ``(n, n)`` device array. This module makes
the mask the *storage layout*: the factor lives as a stacked
``(n_blocks, bs, bs)`` value array plus a static host-side block index, so
device memory drops from O(n²) to O(nnz_blocks · bs²) per subdomain — the
lever that bounds subdomain size on real accelerators (cf. Cheik Ahamed &
Magoulès, arXiv:2108.13162: storage, not FLOPs, limits GPU sub-structuring).

Layout invariants (relied on by the Pallas packed TRSM kernel):

  * blocks are lower-triangular (``col <= row``) on a uniform ``bs`` grid
    padded to ``nb = ceil(n / bs)`` blocks per side;
  * slots are sorted by ``(row, col)`` — row-major CSR-like order — so the
    **diagonal block is the last slot of its row** and ``rowptr`` gives each
    row's contiguous slot range;
  * padded rows/columns beyond ``n`` carry an identity diagonal (factors)
    or zeros (general matrices), so every stored value is exact: packing
    then unpacking reproduces the dense array bit-for-bit.

All index arrays are host-side numpy (compile-time constants inside jit —
the symbolic/numeric split of paper §2.2); only ``values`` lives on device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PackedBlockIndex",
    "PackedBlocks",
    "pack_factor",
    "block_cholesky_packed",
    "packed_tri_solve",
    "packed_symm_matvec",
    "packed_block_index_for",
]


class PackedBlockIndex:
    """Static block index of a packed lower-triangular block layout.

    Attributes:
      n: unpadded matrix dimension.
      bs: uniform block size.
      nb: blocks per side (``ceil(n / bs)``).
      rows / cols: (n_blocks,) block coordinates, sorted by (row, col).
      rowptr: (nb + 1,) CSR-style row pointers into the slot axis.
      slot_table: (nb, nb) slot of block (i, j), -1 where absent.
    """

    def __init__(self, mask: np.ndarray, n: int, bs: int):
        mask = np.asarray(mask, dtype=bool)
        nb = -(-n // bs)
        if mask.shape != (nb, nb):
            raise ValueError(f"mask shape {mask.shape} != ({nb},{nb})")
        mask = np.tril(mask).copy()
        # diagonal blocks must always exist (factorization pivots / padding)
        np.fill_diagonal(mask, True)
        rows, cols = np.nonzero(mask)  # np.nonzero is row-major == (row, col)
        self.n = int(n)
        self.bs = int(bs)
        self.nb = int(nb)
        self.rows = rows.astype(np.int32)
        self.cols = cols.astype(np.int32)
        self.rowptr = np.concatenate(
            [[0], np.cumsum(np.bincount(rows, minlength=nb))]
        ).astype(np.int32)
        table = np.full((nb, nb), -1, dtype=np.int32)
        table[rows, cols] = np.arange(len(rows), dtype=np.int32)
        self.slot_table = table
        self.mask = mask
        self._digest = (self.n, self.bs, self.rows.tobytes(),
                        self.cols.tobytes())

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_mask(cls, mask: np.ndarray, n: int, bs: int) -> "PackedBlockIndex":
        """Index from a symbolic block fill mask (block_symbolic_cholesky)."""
        return cls(mask, n, bs)

    @classmethod
    def full(cls, n: int, bs: int) -> "PackedBlockIndex":
        """All lower-triangular blocks present (no sparsity information)."""
        nb = -(-n // bs)
        return cls(np.tril(np.ones((nb, nb), dtype=bool)), n, bs)

    # -- basic accessors ---------------------------------------------------

    @property
    def n_blocks(self) -> int:
        return len(self.rows)

    @property
    def n_pad(self) -> int:
        return self.nb * self.bs

    @property
    def diag_slots(self) -> np.ndarray:
        """(nb,) slot of each diagonal block (last slot of its row)."""
        return self.rowptr[1:] - 1

    def slot(self, i: int, j: int) -> int:
        """Slot of block (i, j); raises KeyError when structurally absent."""
        s = int(self.slot_table[i, j])
        if s < 0:
            raise KeyError(f"block ({i},{j}) not in packed layout")
        return s

    def row_slots(self, k: int) -> list[tuple[int, int]]:
        """[(j, slot)] of the strictly-subdiagonal blocks in row k (j < k)."""
        lo, hi = int(self.rowptr[k]), int(self.rowptr[k + 1]) - 1
        return [(int(self.cols[t]), t) for t in range(lo, hi)]

    def col_slots(self, k: int) -> list[tuple[int, int]]:
        """[(i, slot)] of the strictly-subdiagonal blocks in column k (i > k)."""
        col = self.slot_table[k + 1:, k]
        return [(k + 1 + i, int(s)) for i, s in enumerate(col) if s >= 0]

    # -- memory accounting -------------------------------------------------

    def packed_nbytes(self, dtype_bytes: int = 8) -> int:
        """Device bytes of ONE packed matrix's value array."""
        return self.n_blocks * self.bs * self.bs * dtype_bytes

    def dense_nbytes(self, dtype_bytes: int = 8) -> int:
        """Device bytes of the dense (n, n) array this layout replaces."""
        return self.n * self.n * dtype_bytes

    # -- pack / unpack (jit-friendly; arbitrary leading batch dims) --------

    def pack(self, A: jax.Array, diag_identity_pad: bool = False) -> jax.Array:
        """Gather the stored blocks of dense ``A`` (..., n, n) into
        (..., n_blocks, bs, bs) values.

        ``diag_identity_pad`` puts 1s on the padded tail of the diagonal
        (keeps factor diagonal blocks triangular-invertible and SPD inputs
        factorizable); the off-diagonal padding is always zero.
        """
        lead = A.shape[:-2]
        if A.shape[-2:] != (self.n, self.n):
            raise ValueError(f"expected (..., {self.n}, {self.n}), "
                             f"got {A.shape}")
        pad = self.n_pad - self.n
        if pad:
            A = jnp.pad(A, [(0, 0)] * len(lead) + [(0, pad), (0, pad)])
            if diag_identity_pad:
                idx = jnp.arange(self.n, self.n_pad)
                A = A.at[..., idx, idx].set(1.0)
        blocks = A.reshape(*lead, self.nb, self.bs, self.nb, self.bs)
        blocks = jnp.swapaxes(blocks, -3, -2)  # (..., nb, nb, bs, bs)
        return blocks[..., self.rows, self.cols, :, :]

    def unpack(self, values: jax.Array) -> jax.Array:
        """Scatter (..., n_blocks, bs, bs) values back to dense (..., n, n).

        Unstored blocks come back as exact zeros; the padded tail (including
        any identity diagonal padding) is trimmed away.
        """
        lead = values.shape[:-3]
        if values.shape[-3:] != (self.n_blocks, self.bs, self.bs):
            raise ValueError(
                f"expected (..., {self.n_blocks}, {self.bs}, {self.bs}), "
                f"got {values.shape}")
        grid = jnp.zeros(lead + (self.nb, self.nb, self.bs, self.bs),
                         values.dtype)
        grid = grid.at[..., self.rows, self.cols, :, :].set(values)
        dense = grid.swapaxes(-3, -2).reshape(
            *lead, self.n_pad, self.n_pad)
        return dense[..., : self.n, : self.n]

    def validate(self, values) -> None:
        """Shape-check a value array (batched or not) against this index."""
        shape = jnp.shape(values)
        if len(shape) < 3 or shape[-3:] != (self.n_blocks, self.bs, self.bs):
            raise ValueError(
                f"values shape {shape} does not end in "
                f"({self.n_blocks}, {self.bs}, {self.bs})")

    # -- identity (static-arg hashability for jit) -------------------------

    def __hash__(self):
        return hash(self._digest)

    def __eq__(self, other):
        return (isinstance(other, PackedBlockIndex)
                and self._digest == other._digest)

    def __repr__(self):
        dense_blocks = self.nb * (self.nb + 1) // 2
        return (f"PackedBlockIndex(n={self.n}, bs={self.bs}, nb={self.nb}, "
                f"n_blocks={self.n_blocks}/{dense_blocks})")


@dataclasses.dataclass
class PackedBlocks:
    """A packed block-sparse matrix (or a stack of them): device values +
    static index. Registered as a pytree with the index as static aux data,
    so it flows through jit / vmap / shard_map like a plain array (the
    leading batch axis, if any, lives on ``values``)."""

    values: jax.Array  # (..., n_blocks, bs, bs)
    index: PackedBlockIndex

    @property
    def nbytes(self) -> int:
        return int(np.prod(jnp.shape(self.values))
                   * jnp.result_type(self.values).itemsize)

    @property
    def batch_shape(self) -> tuple:
        return jnp.shape(self.values)[:-3]

    def unpack(self) -> jax.Array:
        return self.index.unpack(self.values)

    def tree_flatten(self):
        return (self.values,), self.index

    @classmethod
    def tree_unflatten(cls, index, children):
        return cls(children[0], index)


jax.tree_util.register_pytree_node(
    PackedBlocks,
    lambda pb: pb.tree_flatten(),
    PackedBlocks.tree_unflatten,
)


def pack_factor(L: jax.Array, index: PackedBlockIndex) -> PackedBlocks:
    """Pack a dense lower-triangular factor (..., n, n) into the layout,
    identity-padding the diagonal tail so every diagonal block stays
    triangular-invertible."""
    return PackedBlocks(index.pack(L, diag_identity_pad=True), index)


def _solve_lower_right(Lkk: jax.Array, W: jax.Array) -> jax.Array:
    """Solve X Lkkᵀ = W for X (i.e. X = W Lkk⁻ᵀ)."""
    return jax.lax.linalg.triangular_solve(
        Lkk, W, left_side=False, lower=True, transpose_a=True
    )


def block_cholesky_packed(K: jax.Array, index: PackedBlockIndex
                          ) -> PackedBlocks:
    """Cholesky factor of SPD ``K`` computed AND stored in packed form.

    The numerical twin of :func:`repro.sparse.cholesky.block_cholesky` with
    ``mask=index.mask``: the diagonal/panel/update loops walk the static
    block list instead of slicing a dense working matrix, so no (n, n)
    factor is ever materialized. Per-block operations are identical to the
    dense-masked path (padding contributes exact zeros / an exact identity),
    so the stored blocks match it bit-for-bit.
    """
    vals = index.pack(K, diag_identity_pad=True)
    nb = index.nb
    for k in range(nb):
        dk = index.slot(k, k)
        Lkk = jnp.linalg.cholesky(vals[dk])
        vals = vals.at[dk].set(Lkk)
        below = index.col_slots(k)
        panels = {}
        for i, s in below:
            Lik = _solve_lower_right(Lkk, vals[s])
            vals = vals.at[s].set(Lik)
            panels[i] = Lik
        for i, _ in below:
            for j, _ in below:
                if j > i:
                    break
                # symbolic fill guarantees (i, j) is stored: i, j share
                # column k, so eliminating k fills their pairing
                vals = vals.at[index.slot(i, j)].add(
                    -(panels[i] @ panels[j].T))
    return PackedBlocks(vals, index)


def packed_tri_solve(pb: PackedBlocks, b: jax.Array,
                     transpose: bool = False) -> jax.Array:
    """Solve ``L x = b`` (or ``Lᵀ x = b``) with a packed factor, one (n,)
    right-hand side. Block forward/backward substitution over the static
    slot lists; batch with ``jax.vmap`` (see feti.operator.solve_with_factor).
    """
    index = pb.index
    vals = pb.values
    n, bs, nb = index.n, index.bs, index.nb
    pad = index.n_pad - n
    if pad:
        b = jnp.concatenate([b, jnp.zeros((pad,), b.dtype)])
    x = b.reshape(nb, bs)
    if not transpose:
        # forward: x_k = L_kk^{-1} (b_k - sum_{j<k} L_kj x_j)
        for k in range(nb):
            acc = x[k]
            for j, s in index.row_slots(k):
                acc = acc - vals[s] @ x[j]
            xk = jax.lax.linalg.triangular_solve(
                vals[index.slot(k, k)], acc[:, None],
                left_side=True, lower=True)[:, 0]
            x = x.at[k].set(xk)
    else:
        # backward: x_k = L_kk^{-T} (b_k - sum_{i>k} L_ik^T x_i)
        for k in range(nb - 1, -1, -1):
            acc = x[k]
            for i, s in index.col_slots(k):
                acc = acc - vals[s].T @ x[i]
            xk = jax.lax.linalg.triangular_solve(
                vals[index.slot(k, k)], acc[:, None],
                left_side=True, lower=True, transpose_a=True)[:, 0]
            x = x.at[k].set(xk)
    return x.reshape(-1)[:n]


def packed_symm_matvec(pb: PackedBlocks, v: jax.Array) -> jax.Array:
    """``A @ v`` for a symmetric matrix stored as its packed lower triangle.

    Fully vectorized: one batched GEMV over all stored blocks scattered into
    the block rows, plus the transposed contribution of the strictly-lower
    blocks scattered into the block columns.
    """
    index = pb.index
    vals = pb.values
    n, bs, nb = index.n, index.bs, index.nb
    pad = index.n_pad - n
    if pad:
        v = jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
    vb = v.reshape(nb, bs)
    out = jnp.zeros((nb, bs), v.dtype)
    out = out.at[index.rows].add(
        jnp.einsum("bij,bj->bi", vals, vb[index.cols]))
    strict = np.flatnonzero(index.rows != index.cols)
    if strict.size:
        out = out.at[index.cols[strict]].add(
            jnp.einsum("bji,bj->bi", vals[strict], vb[index.rows[strict]]))
    return out.reshape(-1)[:n]


def packed_block_index_for(mask: Optional[np.ndarray], n: int, bs: int
                           ) -> PackedBlockIndex:
    """Index from a fill mask, or the full lower triangle when no symbolic
    information is available (packed storage then still works — it is just
    not smaller than dense)."""
    if mask is None:
        return PackedBlockIndex.full(n, bs)
    return PackedBlockIndex.from_mask(mask, n, bs)
