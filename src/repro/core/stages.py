"""Declarative Schur stage graph: plan many assembly stages JOINTLY.

After the Dirichlet preconditioner landed, the repo had two near-identical
on-device Schur pipelines — the dual operator F̃ = (L⁻¹B̃ᵀ)ᵀ(L⁻¹B̃ᵀ) and
the primal boundary S_b = K_bb − K_bi·K_ii⁻¹·K_ib — planned, padded and
cached separately. This module is the unification layer:

  * a :class:`StageSpec` declares one stage symbolically: a builder
    producing its stepped metadata + factor fill mask at any candidate
    block size, a content fingerprint of its sparsity inputs, its storage
    restriction and dtype, and (optionally) which other stage's factor it
    shares (``share_factor_of`` — the interior-factor dedup);
  * a :class:`StageGraph` plans ALL stages under ONE cache key
    (``SPACE_VERSION`` 4: a joint graph entry, not per-stage entries) and
    resolves each stage to concrete metadata + assembler;
  * execution stays with the caller (feti.assembly compiles one prep over
    the resolved stages) — the graph is symbolic/planning state, so a
    third pipeline (a GenEO coarse stage, a mixed-precision stage) is a
    new StageSpec plus its input wiring, nothing else.

See docs/stage_graph.md for the model, the fusion + factor-sharing rules,
and the joint plan-cache key contents.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Callable, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.autotune import (
    SPACE_VERSION,
    Plan,
    default_block_sizes,
    plan_cache_dir,
    plan_from_builder,
)
from repro.core.schur import SchurAssemblyConfig, make_assembler
from repro.core.stepped import SteppedMeta
from repro.launch.roofline import DeviceModel, detect_device

__all__ = [
    "StageSpec",
    "StageGraph",
    "GraphPlan",
    "ResolvedStage",
]

# (block_size, rhs_block_size) -> (stepped metadata, factor block fill mask)
StageBuilder = Callable[[int, int], Tuple[SteppedMeta, Optional[np.ndarray]]]


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One Schur assembly stage, declared symbolically.

    Attributes:
      name: unique stage name — the plan slot, the key of the stage's
        output in :class:`~repro.feti.assembly.ClusterState`, and part of
        the joint cache key.
      builder: ``(block_size, rhs_block_size) -> (meta, block_mask)`` —
        the stage's stepped metadata and symbolic factor fill mask at any
        candidate block size (the same contract as
        :func:`repro.core.autotune.plan_from_builder`).
      fingerprint: content hash of the stage's sparsity inputs (pivots,
        factor structure, orderings) — what makes the joint cache key.
      n: factor dimension; drives the default block-size candidates.
      storage: restrict this stage's search to one factor layout
        ("dense" | "packed"); None searches both.
      dtype_bytes: element size of the stage's arrays (8 = f64); enters
        the cost model, recorded for per-stage accounting.
      block_sizes: override the candidate block sizes (None = derived
        from ``n``).
      share_factor_of: name of an earlier stage whose factor's leading
        principal block this stage reuses instead of factorizing its own
        matrix (the interior-factor dedup). Planning still searches this
        stage's assembly space; only the factorization is elided — the
        caller wires the shared factor at execution time.
      measure: per-stage override of the graph-level measurement policy
        (e.g. "never" for a stage whose assembly is not executed, like
        the dual stage of an implicit solve); None inherits.
    """

    name: str
    builder: StageBuilder
    fingerprint: str
    n: int
    storage: Optional[str] = None
    dtype_bytes: int = 8
    block_sizes: Optional[Tuple[int, ...]] = None
    share_factor_of: Optional[str] = None
    measure: Optional[str] = None

    def candidate_block_sizes(self) -> Tuple[int, ...]:
        return self.block_sizes or default_block_sizes(self.n)


@dataclasses.dataclass
class ResolvedStage:
    """A stage bound to a concrete config: metadata, mask and assembler."""

    spec: StageSpec
    cfg: SchurAssemblyConfig
    meta: SteppedMeta
    mask: Optional[np.ndarray]
    plan: Optional[Plan] = None

    def assembler(self):
        """``assemble(L, Bt) -> F`` for this stage (core.schur)."""
        return make_assembler(self.meta, self.cfg, self.mask)


@dataclasses.dataclass
class GraphPlan:
    """The jointly-planned result: one cache entry covering every stage."""

    key: str
    device: str
    plans: dict  # stage name -> Plan
    from_cache: bool = False

    def __getitem__(self, name: str) -> Plan:
        return self.plans[name]

    def summary(self) -> str:
        lines = [f"graph[{self.device}] {len(self.plans)} stage(s), "
                 f"joint key {self.key[:12]}"
                 f"{' (cached)' if self.from_cache else ''}"]
        for name, plan in self.plans.items():
            lines.append(f"[{name}]")
            lines.extend("  " + ln for ln in plan.summary().splitlines())
        return "\n".join(lines)


def _graph_cache_path(key: str) -> str:
    return os.path.join(plan_cache_dir(), f"graph-{key}.json")


def _load_graph_cached(key: str) -> Optional[GraphPlan]:
    try:
        with open(_graph_cache_path(key)) as f:
            d = json.load(f)
        plans = {name: Plan.from_json(p) for name, p in d["stages"].items()}
        return GraphPlan(key=key, device=d["device"], plans=plans,
                         from_cache=True)
    except (OSError, ValueError, TypeError, KeyError):
        return None


def _store_graph(gp: GraphPlan) -> None:
    root = plan_cache_dir()
    try:
        os.makedirs(root, exist_ok=True)
        tmp = os.path.join(root, f".graph-{gp.key}.tmp.{os.getpid()}")
        with open(tmp, "w") as f:
            json.dump({"device": gp.device,
                       "stages": {n: p.to_json()
                                  for n, p in gp.plans.items()}}, f, indent=1)
        os.replace(tmp, _graph_cache_path(gp.key))
    except OSError:
        pass  # best-effort, like the single-plan cache


class StageGraph:
    """An ordered set of :class:`StageSpec` planned as ONE unit.

    The joint cache key hashes every stage's (name, fingerprint, storage,
    block sizes, factor-sharing edge) plus the device kind and
    ``SPACE_VERSION`` — any stage changing invalidates the whole graph
    entry, so the stages can never be served mutually-stale plans.
    """

    def __init__(self, stages: Sequence[StageSpec]):
        names = [s.name for s in stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")
        by_name = {}
        for s in stages:
            if s.share_factor_of is not None \
                    and s.share_factor_of not in by_name:
                raise ValueError(
                    f"stage {s.name!r} shares the factor of "
                    f"{s.share_factor_of!r}, which is not an earlier stage")
            by_name[s.name] = s
        self.stages: Tuple[StageSpec, ...] = tuple(stages)
        self.by_name = by_name

    def __iter__(self):
        return iter(self.stages)

    def __getitem__(self, name: str) -> StageSpec:
        return self.by_name[name]

    # -- joint planning ----------------------------------------------------

    def joint_key(self, device: DeviceModel, measured: bool) -> str:
        h = hashlib.sha256()
        h.update(f"v{SPACE_VERSION}:graph:{device.kind}:"
                 f"{int(measured)}:".encode())
        for s in self.stages:
            bss = ",".join(str(b) for b in sorted(s.candidate_block_sizes()))
            h.update(f"|{s.name}:{s.fingerprint}:{s.storage or 'any'}:"
                     f"{s.dtype_bytes}:{bss}:"
                     f"{s.share_factor_of or '-'}:"
                     f"{s.measure or 'inherit'}".encode())
        return h.hexdigest()

    def plan(
        self,
        *,
        measure: str = "auto",
        device: Optional[DeviceModel] = None,
        cache: bool = True,
        top_k: int = 8,
        reps: int = 5,
    ) -> GraphPlan:
        """Plan every stage; hit or populate ONE joint cache entry.

        Per-stage searches reuse :func:`plan_from_builder` (same cost
        model, same two-stage measured refinement, same never-slower-than
        guards) with that function's own cache bypassed — the graph entry
        is the only cache at this level.
        """
        device = device or detect_device()
        key = self.joint_key(device, measured=(measure == "auto"))
        if cache:
            hit = _load_graph_cached(key)
            if hit is not None and set(hit.plans) == set(self.by_name):
                return hit
        plans = {}
        for s in self.stages:
            plans[s.name] = plan_from_builder(
                s.builder, s.fingerprint,
                block_sizes=s.candidate_block_sizes(), n_hint=s.n,
                measure=s.measure or measure, top_k=top_k, device=device,
                cache=False, reps=reps, storage=s.storage, stage=s.name)
        gp = GraphPlan(key=key, device=device.kind, plans=plans)
        if cache:
            _store_graph(gp)
        return gp

    # -- resolution --------------------------------------------------------

    def resolve(
        self,
        cfgs: Mapping[str, SchurAssemblyConfig],
        plans: Optional[Mapping[str, Plan]] = None,
    ) -> dict:
        """Bind every stage to a concrete config: build the stepped
        metadata + fill mask it will execute with. ``cfgs`` maps stage
        name -> config (e.g. ``{name: gplan[name].cfg}`` after
        :meth:`plan`, or explicit configs without planning)."""
        out = {}
        for s in self.stages:
            cfg = cfgs[s.name]
            meta, mask = s.builder(cfg.block_size, cfg.rhs_bs)
            out[s.name] = ResolvedStage(
                spec=s, cfg=cfg, meta=meta, mask=mask,
                plan=None if plans is None else plans.get(s.name))
        return out
