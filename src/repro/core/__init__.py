"""The paper's primary contribution: sparsity-utilizing assembly of Schur
complement matrices (dual operators) in domain decomposition methods.

Public API:
  * stepped-shape analysis and metadata: :mod:`repro.core.stepped`
  * TRSM variants (RHS / factor splitting + pruning): :mod:`repro.core.trsm`
  * SYRK variants (input / output splitting): :mod:`repro.core.syrk`
  * the assembly pipeline + config: :mod:`repro.core.schur`
  * the plan autotuner + content-addressed plan cache:
    :mod:`repro.core.autotune` (``plan`` façade below)
  * the declarative stage graph (many Schur stages, one joint plan):
    :mod:`repro.core.stages` (docs/stage_graph.md)
"""
from repro.core.schur import (
    SchurAssemblyConfig,
    assemble_schur,
    assembly_flops,
    make_assembler,
    schur_dense_baseline,
)
from repro.core.stepped import (
    SteppedMeta,
    build_stepped_meta,
    column_pivots,
    row_trails,
    shared_envelope,
    stepped_permutation,
)
from repro.core.syrk import syrk_dense, syrk_input_split, syrk_output_split
from repro.core.trsm import (
    trsm_dense,
    trsm_factor_split,
    trsm_factor_split_packed,
    trsm_rhs_split,
)
from repro.core.autotune import (
    Plan,
    assembly_cost,
    enumerate_space,
    plan_assembly,
    plan_from_builder,
)
from repro.core.stages import GraphPlan, ResolvedStage, StageGraph, StageSpec

# the façade: `from repro.core import plan; plan(bt_pattern).cfg`
plan = plan_assembly

__all__ = [
    "GraphPlan",
    "Plan",
    "ResolvedStage",
    "SchurAssemblyConfig",
    "StageGraph",
    "StageSpec",
    "SteppedMeta",
    "assembly_cost",
    "enumerate_space",
    "plan",
    "plan_assembly",
    "plan_from_builder",
    "assemble_schur",
    "assembly_flops",
    "build_stepped_meta",
    "column_pivots",
    "make_assembler",
    "row_trails",
    "schur_dense_baseline",
    "shared_envelope",
    "stepped_permutation",
    "syrk_dense",
    "syrk_input_split",
    "syrk_output_split",
    "trsm_dense",
    "trsm_factor_split",
    "trsm_factor_split_packed",
    "trsm_rhs_split",
]
