"""The sparsity-utilizing Schur complement assembly pipeline (paper §3).

Assembles the dense local dual operator

    F̃ = B̃ L⁻ᵀ L⁻¹ B̃ᵀ = (L⁻¹B̃ᵀ)ᵀ (L⁻¹B̃ᵀ) = Yᵀ Y    (paper eq. 14)

from the Cholesky factor ``L`` of the regularized subdomain matrix and the
gluing matrix ``B̃ᵀ``, wisely utilizing the sparsity of both:

  1. column-permute B̃ᵀ into the *stepped* shape (stepped.py),
  2. TRSM with RHS- or factor-splitting (trsm.py) — optionally the Pallas
     stepped_trsm kernel,
  3. SYRK with input- or output-splitting (syrk.py) — optionally the Pallas
     stepped_syrk kernel,
  4. permute the resulting SC back to the original multiplier order.

The selectable ``SchurAssemblyConfig`` reproduces every row of the paper's
Table 1 / Figure 6 design space, plus the dense baseline of [9] (§3.1).
The paper picks the row by hand; :mod:`repro.core.autotune` picks it
automatically (pass ``cfg="auto"`` to the FETI preprocessing/solver).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import syrk as syrk_mod
from repro.core import trsm as trsm_mod
from repro.core.stepped import SteppedMeta

__all__ = [
    "SchurAssemblyConfig",
    "make_assembler",
    "assemble_schur",
    "schur_dense_baseline",
    "assembly_flops",
]

TRSM_VARIANTS = ("dense", "rhs_split", "factor_split")
SYRK_VARIANTS = ("dense", "input_split", "output_split")
STORAGE_VARIANTS = ("dense", "packed")


def _default_storage() -> str:
    """Process-wide default factor storage; the CI packed lane runs the
    whole suite with ``REPRO_STORAGE=packed`` to prove the packed layout is
    a drop-in default, not a special-cased code path."""
    return os.environ.get("REPRO_STORAGE", "dense")


@dataclasses.dataclass(frozen=True)
class SchurAssemblyConfig:
    """Configuration of the SC assembly (paper §3 / Table 1).

    Attributes:
      trsm_variant: "dense" (baseline [9]) | "rhs_split" | "factor_split".
      syrk_variant: "dense" (baseline [9]) | "input_split" | "output_split".
      block_size: factor row-block size (paper's "S"; Table 1 optimum ≈500
        on GPU-3D, our TPU tiles default to 128-aligned sizes).
      rhs_block_size: RHS column-block size (defaults to block_size).
      prune: skip structurally-zero factor blocks in the factor-split GEMM
        updates (needs a block fill mask; paper's "pruning").
      use_pallas: dispatch TRSM/SYRK to the Pallas TPU kernels.
      fused: run TRSM→SYRK as ONE Pallas megakernel (stepped_trsm_syrk):
        the solution panel Y stays in VMEM across the stage boundary
        instead of round-tripping HBM between the two kernels. Requires
        ``use_pallas``; the ``trsm_variant``/``syrk_variant`` fields are
        ignored (the megakernel's schedule is rhs-split × output-split by
        construction). Enumerated by the autotuner as its own candidate
        family, so it is only ever picked when measured faster.
      interpret: run Pallas kernels in interpret mode (CPU validation).
      storage: factor storage layout, "dense" (a (n, n) array) or "packed"
        (a :class:`repro.sparse.packed.PackedBlocks`: the symbolic fill
        mask IS the layout — O(nnz_blocks) device memory). Packed storage
        is native for ``factor_split`` TRSM and the Pallas kernels; the
        "dense"/"rhs_split" TRSM variants densify the factor transiently
        inside the compiled program (correct, but without the memory win
        during that op). Default comes from ``$REPRO_STORAGE`` ("dense").
    """

    trsm_variant: str = "factor_split"
    syrk_variant: str = "input_split"
    block_size: int = 128
    rhs_block_size: Optional[int] = None
    prune: bool = True
    use_pallas: bool = False
    fused: bool = False
    interpret: bool = False
    storage: str = dataclasses.field(default_factory=_default_storage)

    def __post_init__(self):
        if self.trsm_variant not in TRSM_VARIANTS:
            raise ValueError(f"trsm_variant must be one of {TRSM_VARIANTS}")
        if self.syrk_variant not in SYRK_VARIANTS:
            raise ValueError(f"syrk_variant must be one of {SYRK_VARIANTS}")
        if self.storage not in STORAGE_VARIANTS:
            raise ValueError(f"storage must be one of {STORAGE_VARIANTS}")
        if self.fused and not self.use_pallas:
            raise ValueError("fused=True is the Pallas TRSM→SYRK megakernel "
                             "and requires use_pallas=True")

    @property
    def rhs_bs(self) -> int:
        return self.rhs_block_size or self.block_size

    @property
    def is_dense_baseline(self) -> bool:
        """True when no variant exploits the stepped order — the column
        permutation is then a mathematical no-op and is skipped."""
        return (self.trsm_variant == "dense" and self.syrk_variant == "dense"
                and not self.fused)


def _coerce_factor(L, meta, cfg, block_mask):
    """Align the runtime factor representation with ``cfg.storage``.

    Packed configs pack a dense factor on the fly (index from the block
    mask, or the full lower triangle when no symbolic info is available);
    dense configs unpack a packed factor. Either coercion happens inside
    the compiled program — callers that preprocess in the right layout
    (feti.assembly) never pay it.
    """
    from repro.sparse.packed import (
        PackedBlocks,
        pack_factor,
        packed_block_index_for,
    )

    packed = isinstance(L, PackedBlocks)
    if cfg.storage == "packed" and not packed:
        index = packed_block_index_for(block_mask, meta.n, meta.block_size)
        return pack_factor(L, index)
    if cfg.storage == "dense" and packed:
        return L.unpack()
    return L


def _trsm_syrk_fused(L, Bp, meta, cfg):
    """The fused Pallas megakernel: F = (L⁻¹Bp)ᵀ(L⁻¹Bp) in one kernel,
    Y held in VMEM across the TRSM→SYRK boundary (kernels/stepped_trsm_syrk).
    Dense and packed factors both supported — the wrapper dispatches."""
    from repro.kernels import ops as kops  # lazy: avoid import cycle

    return kops.stepped_trsm_syrk(L, Bp, meta, interpret=cfg.interpret)


def _trsm(L, Bp, meta, cfg, block_mask):
    from repro.sparse.packed import PackedBlocks

    packed = isinstance(L, PackedBlocks)
    if cfg.use_pallas and cfg.trsm_variant != "dense":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        if packed:
            return kops.stepped_trsm_packed(L, Bp, meta,
                                            interpret=cfg.interpret)
        return kops.stepped_trsm(L, Bp, meta, interpret=cfg.interpret)
    if packed and cfg.trsm_variant == "factor_split":
        # pruning is structural in packed storage: absent blocks don't exist
        return trsm_mod.trsm_factor_split_packed(L, Bp, meta)
    if packed:
        # dense/rhs_split TRSM need the trailing subfactor as one array:
        # densify transiently inside the compiled program
        L = L.unpack()
    if cfg.trsm_variant == "dense":
        return trsm_mod.trsm_dense(L, Bp)
    if cfg.trsm_variant == "rhs_split":
        return trsm_mod.trsm_rhs_split(L, Bp, meta)
    return trsm_mod.trsm_factor_split(
        L, Bp, meta, block_mask=block_mask if cfg.prune else None
    )


def _syrk(Y, meta, cfg):
    if cfg.use_pallas and cfg.syrk_variant != "dense":
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.stepped_syrk(Y, meta, interpret=cfg.interpret)
    if cfg.syrk_variant == "dense":
        return syrk_mod.syrk_dense(Y)
    if cfg.syrk_variant == "input_split":
        return syrk_mod.syrk_input_split(Y, meta)
    return syrk_mod.syrk_output_split(Y, meta)


def make_assembler(
    meta: SteppedMeta,
    cfg: SchurAssemblyConfig,
    block_mask: Optional[np.ndarray] = None,
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Build the (jit-friendly) assembler for one sparsity pattern.

    Returns ``assemble(L, Bt) -> F`` where ``Bt`` is (n, m) in the ORIGINAL
    column order and ``F`` is the (m, m) dense SC in the original order.
    The permutation in/out is part of the compiled program (paper §4.4
    includes it in the measured assembly, so do we).

    ``L`` is a dense (n, n) factor or a packed
    :class:`~repro.sparse.packed.PackedBlocks` — whichever does not match
    ``cfg.storage`` is coerced inside the compiled program, so callers that
    preprocess in the configured layout pay nothing.
    """
    if cfg.is_dense_baseline:
        # dense TRSM + dense SYRK never look at the stepped metadata, so
        # the in/out permutation would be pure overhead: F = (L⁻¹Bᵀ)ᵀL⁻¹Bᵀ
        # is permutation-equivariant. This makes the dense/dense candidate
        # of the autotuner cost-identical to schur_dense_baseline.
        def assemble_dense(L, Bt: jax.Array) -> jax.Array:
            Y = _trsm(_coerce_factor(L, meta, cfg, block_mask), Bt, meta,
                      cfg, block_mask)
            return _syrk(Y, meta, cfg)

        return assemble_dense

    perm = jnp.asarray(meta.perm)
    inv = jnp.asarray(meta.inv_perm)

    def assemble(L, Bt: jax.Array) -> jax.Array:
        Bp = Bt[:, perm]
        Lc = _coerce_factor(L, meta, cfg, block_mask)
        if cfg.fused:
            Fp = _trsm_syrk_fused(Lc, Bp, meta, cfg)
        else:
            Y = _trsm(Lc, Bp, meta, cfg, block_mask)
            Fp = _syrk(Y, meta, cfg)
        # permute back: F[i, j] = Fp[inv[i], inv[j]]
        return Fp[inv][:, inv]

    return assemble


def assemble_schur(
    L: jax.Array,
    Bt: jax.Array,
    meta: SteppedMeta,
    cfg: SchurAssemblyConfig,
    block_mask: Optional[np.ndarray] = None,
) -> jax.Array:
    """One-shot convenience wrapper around :func:`make_assembler`."""
    return make_assembler(meta, cfg, block_mask)(L, Bt)


def schur_dense_baseline(L: jax.Array, Bt: jax.Array) -> jax.Array:
    """The original algorithm of [9] (paper §3.1): dense TRSM + dense SYRK.

    No permutation, no splitting — the baseline every speedup in the paper
    (and EXPERIMENTS.md §Paper-repro) is measured against.
    """
    Y = trsm_mod.trsm_dense(L, Bt)
    return syrk_mod.syrk_dense(Y)


def assembly_flops(meta: SteppedMeta, cfg: SchurAssemblyConfig) -> dict:
    """FLOP model of one assembly under ``cfg`` (lower-triangle SYRK)."""
    if cfg.fused:
        # the megakernel's schedule is per-stripe forward substitution with
        # the stepped skip (= rhs_split flops) + output-tile contraction
        # with the per-stripe lower bound (= output_split flops)
        trsm = meta.flops_trsm_rhs_split()
        syrk = meta.flops_syrk_output_split()
        return {"trsm": trsm, "syrk": syrk, "total": trsm + syrk}
    trsm = {
        "dense": meta.flops_trsm_dense,
        "rhs_split": meta.flops_trsm_rhs_split,
        "factor_split": meta.flops_trsm_factor_split,
    }[cfg.trsm_variant]()
    syrk = {
        "dense": meta.flops_syrk_dense,
        "input_split": meta.flops_syrk_input_split,
        "output_split": meta.flops_syrk_output_split,
    }[cfg.syrk_variant]()
    return {"trsm": trsm, "syrk": syrk, "total": trsm + syrk}
