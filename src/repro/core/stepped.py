"""Stepped-shape analysis of the RHS matrix B̃ᵀ (paper §3).

The paper's optimization pivots on permuting the *columns* of B̃ᵀ (never the
rows — that would disturb the fill-reducing permutation of K) so the column
pivots (first nonzero per column) descend monotonically from left to right.
This "stepped" shape is what lets TRSM and SYRK skip the zero region above
the pivots.

Everything in this module is HOST-SIDE (numpy): the sparsity *pattern* of a
FETI decomposition is fixed across the multi-step simulation (symbolic /
numeric split, paper §2.2), so the metadata computed here is baked into the
compiled XLA program once and reused every re-assembly.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "column_pivots",
    "row_trails",
    "stepped_permutation",
    "SteppedMeta",
    "build_stepped_meta",
]


def column_pivots(pattern: np.ndarray) -> np.ndarray:
    """First nonzero row index of each column; ``n`` for empty columns.

    ``pattern`` is a boolean (or truthy) (n, m) array representing the
    sparsity pattern of B̃ᵀ (rows = subdomain DOFs in fill-reducing order,
    columns = local Lagrange multipliers).
    """
    pattern = np.asarray(pattern) != 0
    n, m = pattern.shape
    has = pattern.any(axis=0)
    piv = np.where(has, pattern.argmax(axis=0), n)
    return piv.astype(np.int64)


def row_trails(pattern: np.ndarray) -> np.ndarray:
    """Last nonzero column index of each row; ``-1`` for empty rows."""
    pattern = np.asarray(pattern) != 0
    n, m = pattern.shape
    rev = pattern[:, ::-1]
    has = pattern.any(axis=1)
    trail = np.where(has, m - 1 - rev.argmax(axis=1), -1)
    return trail.astype(np.int64)


def stepped_permutation(pivots: np.ndarray) -> np.ndarray:
    """Column permutation (stable sort by pivot) producing the stepped shape.

    Returns ``perm`` such that ``Bt[:, perm]`` has non-decreasing column
    pivots. Ties keep original order (stable), matching the paper's "equal
    column pivot indices are allowed in neighbouring columns".
    """
    return np.argsort(pivots, kind="stable").astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SteppedMeta:
    """Static per-pattern metadata driving the blocked stepped kernels.

    All integer arrays are host-side numpy; shapes/sizes are Python ints so
    they are compile-time constants inside jit.

    Attributes:
      n: factor dimension (rows of B̃ᵀ).
      m: number of RHS columns (local Lagrange multipliers).
      block_size: factor row-block size ``b`` (paper Table 1 "S <size>").
      rhs_block_size: RHS column-block size ``cb``.
      perm: column permutation applied to B̃ᵀ to reach stepped shape.
      inv_perm: inverse permutation (maps stepped index -> original index).
      pivots: per (permuted) column first-nonzero row; non-decreasing.
      num_row_blocks / num_col_blocks: ceil-divided block counts.
      widths: ``widths[k]`` = number of (permuted) columns active in factor
        row-block k, i.e. ``#{c : pivots[c] < end_k}``. Non-decreasing.
      col_starts: ``col_starts[c]`` = first possibly-nonzero row of RHS
        column-block c (its smallest pivot); non-decreasing.
    """

    n: int
    m: int
    block_size: int
    rhs_block_size: int
    perm: np.ndarray
    inv_perm: np.ndarray
    pivots: np.ndarray
    widths: np.ndarray
    col_starts: np.ndarray

    @property
    def num_row_blocks(self) -> int:
        return -(-self.n // self.block_size)

    @property
    def num_col_blocks(self) -> int:
        return -(-self.m // self.rhs_block_size)

    def row_block(self, k: int) -> tuple[int, int]:
        return k * self.block_size, min((k + 1) * self.block_size, self.n)

    def col_block(self, c: int) -> tuple[int, int]:
        return c * self.rhs_block_size, min((c + 1) * self.rhs_block_size, self.m)

    def width_at_row(self, r: int) -> int:
        """Number of columns with pivot <= r (active width at row r)."""
        return int(np.searchsorted(self.pivots, r, side="right"))

    # -- FLOP model (MACs counted as 2 flops), used by benchmarks & §Perf --

    def flops_trsm_dense(self) -> int:
        return self.n * self.n * self.m  # n^2/2 solve * m cols * 2 flops

    def flops_trsm_rhs_split(self) -> int:
        total = 0
        for c in range(self.num_col_blocks):
            c0, c1 = self.col_block(c)
            s = int(self.col_starts[c])
            nn = self.n - s
            total += nn * nn * (c1 - c0)
        return total

    def flops_trsm_factor_split(self) -> int:
        total = 0
        for k in range(self.num_row_blocks):
            r0, r1 = self.row_block(k)
            b = r1 - r0
            w = int(self.widths[k])
            total += b * b * w  # diagonal TRSM
            total += 2 * (self.n - r1) * b * w  # GEMM update
        return total

    def flops_syrk_dense(self) -> int:
        return self.n * self.m * self.m  # m^2/2 outputs * n * 2 flops

    def flops_syrk_input_split(self) -> int:
        total = 0
        for k in range(self.num_row_blocks):
            r0, r1 = self.row_block(k)
            w = int(self.widths[k])
            total += (r1 - r0) * w * w
        return total

    def flops_syrk_output_split(self) -> int:
        total = 0
        for i in range(self.num_col_blocks):
            i0, i1 = self.col_block(i)
            s = int(self.col_starts[i])
            kk = self.n - s
            # diagonal block (SYRK) + row of off-diagonal blocks (GEMM)
            total += kk * (i1 - i0) * (i1 - i0)
            total += 2 * kk * (i1 - i0) * i0
        return total


def build_stepped_meta(
    pattern: np.ndarray,
    block_size: int = 128,
    rhs_block_size: int | None = None,
    presorted: bool = False,
) -> SteppedMeta:
    """Analyse a B̃ᵀ sparsity pattern and build the stepped metadata.

    Args:
      pattern: (n, m) boolean-ish sparsity pattern of B̃ᵀ in the factor's
        (fill-reducing) row order and the original column order.
      block_size: factor row-block size (paper's block-size hyperparameter).
      rhs_block_size: RHS column-block size; defaults to ``block_size``.
      presorted: if True, assume columns are already stepped (perm=identity).
    """
    pattern = np.asarray(pattern) != 0
    n, m = pattern.shape
    if rhs_block_size is None:
        rhs_block_size = block_size
    piv_orig = column_pivots(pattern)
    if presorted:
        perm = np.arange(m, dtype=np.int64)
    else:
        perm = stepped_permutation(piv_orig)
    pivots = piv_orig[perm]
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(m, dtype=np.int64)

    nb = -(-n // block_size)
    widths = np.empty(nb, dtype=np.int64)
    for k in range(nb):
        end_k = min((k + 1) * block_size, n)
        widths[k] = np.searchsorted(pivots, end_k - 1, side="right")

    cb = -(-m // rhs_block_size)
    col_starts = np.empty(cb, dtype=np.int64)
    for c in range(cb):
        c0 = c * rhs_block_size
        col_starts[c] = min(pivots[c0], n)

    return SteppedMeta(
        n=n,
        m=m,
        block_size=int(block_size),
        rhs_block_size=int(rhs_block_size),
        perm=perm,
        inv_perm=inv_perm,
        pivots=pivots,
        widths=widths,
        col_starts=col_starts,
    )


def build_stepped_meta_from_pivots(
    pivots_orig: np.ndarray,
    n: int,
    block_size: int = 128,
    rhs_block_size: int | None = None,
) -> SteppedMeta:
    """Build metadata directly from per-column pivot rows (no dense pattern).

    Used by the dry-run for production-sized subdomains: FETI gluing columns
    have exactly one nonzero, so the pivot row IS the pattern, and the dense
    (n × m) B̃ᵀ never needs to exist host-side.
    """
    pivots_orig = np.asarray(pivots_orig, dtype=np.int64)
    m = pivots_orig.shape[0]
    if rhs_block_size is None:
        rhs_block_size = block_size
    perm = stepped_permutation(pivots_orig)
    pivots = pivots_orig[perm]
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(m, dtype=np.int64)

    nb = -(-n // block_size)
    widths = np.searchsorted(
        pivots, np.minimum((np.arange(nb) + 1) * block_size, n) - 1,
        side="right",
    ).astype(np.int64)
    cb = -(-m // rhs_block_size)
    col_starts = np.minimum(pivots[np.arange(cb) * rhs_block_size], n)

    return SteppedMeta(
        n=n, m=m, block_size=int(block_size),
        rhs_block_size=int(rhs_block_size), perm=perm, inv_perm=inv_perm,
        pivots=pivots, widths=widths, col_starts=col_starts.astype(np.int64),
    )


def shared_envelope(metas: Sequence[SteppedMeta]) -> SteppedMeta:
    """Combine several same-shape metas into one conservative envelope.

    Used to batch subdomains with *different* B̃ᵀ patterns through one
    compiled program (the TPU analogue of the paper's 16 CUDA streams):
    skipping is only applied where *all* batched patterns are zero, which
    keeps the batched kernel correct for every member.
    """
    first = metas[0]
    for me in metas[1:]:
        if (me.n, me.m, me.block_size, me.rhs_block_size) != (
            first.n,
            first.m,
            first.block_size,
            first.rhs_block_size,
        ):
            raise ValueError("shared_envelope requires identical shapes/blocks")
    widths = np.max([me.widths for me in metas], axis=0)
    col_starts = np.min([me.col_starts for me in metas], axis=0)
    pivots = np.min([me.pivots for me in metas], axis=0)
    return SteppedMeta(
        n=first.n,
        m=first.m,
        block_size=first.block_size,
        rhs_block_size=first.rhs_block_size,
        perm=np.arange(first.m, dtype=np.int64),
        inv_perm=np.arange(first.m, dtype=np.int64),
        pivots=pivots,
        widths=widths,
        col_starts=col_starts,
    )
