"""Sparsity-utilizing SYRK variants (paper §3.3).

Computes ``F = Yᵀ Y`` for the stepped matrix ``Y`` produced by TRSM (zeros
above the column pivots are preserved by forward substitution, so Y carries
the same stepped envelope as B̃ᵀ).

Variants:
  * ``syrk_dense``        — baseline full SYRK (paper §3.1).
  * ``syrk_input_split``  — split Y into row blocks (paper Fig. 4a): row
                            block k is nonzero only in its leading
                            ``widths[k]`` columns, so each partial SYRK
                            updates only the top-left ``w×w`` principal
                            submatrix of the output.
  * ``syrk_output_split`` — tile the output (paper Fig. 4b): output block
                            row I needs input rows starting only at the
                            pivot of column block I (k-dimension reduction);
                            the diagonal block is a small SYRK, the blocks
                            to its left are GEMMs.

The result is returned as the full symmetric matrix (both triangles filled):
the dense F̃ᵢ is consumed by GEMV in every PCPG iteration, and on TPU a full
symmetric GEMV is preferable to a triangular-packed one. FLOP accounting in
stepped.SteppedMeta counts lower-triangle work only, matching the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stepped import SteppedMeta

__all__ = ["syrk_dense", "syrk_input_split", "syrk_output_split"]


def syrk_dense(Y: jax.Array) -> jax.Array:
    """Baseline: full dense SYRK F = YᵀY."""
    return Y.T @ Y


def syrk_input_split(Y: jax.Array, meta: SteppedMeta) -> jax.Array:
    """Input (row-block) splitting, paper Fig. 4a."""
    if Y.shape != (meta.n, meta.m):
        raise ValueError(f"Y shape {Y.shape} != meta ({meta.n},{meta.m})")
    F = jnp.zeros((meta.m, meta.m), dtype=Y.dtype)
    for k in range(meta.num_row_blocks):
        r0, r1 = meta.row_block(k)
        w = int(meta.widths[k])
        if w == 0:
            continue
        Yk = Y[r0:r1, :w]
        F = F.at[:w, :w].add(Yk.T @ Yk)
    return F


def syrk_output_split(Y: jax.Array, meta: SteppedMeta) -> jax.Array:
    """Output (block-row of F) splitting, paper Fig. 4b.

    For output block row I (columns of F up to block I), contributions from
    input rows above ``col_starts[I]`` vanish because every column in block
    I has its pivot at or below that row. The diagonal block is an inner
    SYRK; the off-diagonal strip ``F[I, :I]`` is one GEMM. Both triangles of
    F are written (the strip is mirrored).
    """
    if Y.shape != (meta.n, meta.m):
        raise ValueError(f"Y shape {Y.shape} != meta ({meta.n},{meta.m})")
    F = jnp.zeros((meta.m, meta.m), dtype=Y.dtype)
    for i in range(meta.num_col_blocks):
        i0, i1 = meta.col_block(i)
        s = int(meta.col_starts[i])
        if s >= meta.n:  # structurally zero columns -> zero row/col of F
            continue
        Ci = Y[s:, i0:i1]
        F = F.at[i0:i1, i0:i1].set(Ci.T @ Ci)
        if i0 > 0:
            strip = Ci.T @ Y[s:, :i0]
            F = F.at[i0:i1, :i0].set(strip)
            F = F.at[:i0, i0:i1].set(strip.T)
    return F
