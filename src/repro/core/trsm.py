"""Sparsity-utilizing TRSM variants (paper §3.2).

Solves ``L Y = B`` for a lower-triangular factor ``L`` and a *stepped* RHS
``B`` (columns permuted so pivots are non-decreasing, see stepped.py).

Variants:
  * ``trsm_dense``         — the baseline of [Homola et al. 2502.08382]: one
                             library TRSM on the full matrices (paper §3.1).
  * ``trsm_rhs_split``     — RHS column-block splitting (paper Fig. 3a): each
                             column block only needs the trailing subfactor
                             starting at its highest column pivot.
  * ``trsm_factor_split``  — factor blocking (paper Fig. 3b): per diagonal
                             block, a small TRSM restricted to the columns
                             that are nonzero so far, then a GEMM update of
                             the rows below. With a block fill mask this also
                             *prunes* structurally-zero factor blocks from the
                             update (paper's "pruning", CHOLMOD-supernodal
                             style — on TPU, zero *blocks* rather than zero
                             rows, since the MXU wants dense 128-ish tiles).

All loops below are Python loops over compile-time-constant block indices:
the stepped metadata is fixed per decomposition (symbolic/numeric split), so
XLA sees a fully static program and each (pattern, config) compiles once.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stepped import SteppedMeta

__all__ = [
    "trsm_dense",
    "trsm_rhs_split",
    "trsm_factor_split",
    "trsm_factor_split_packed",
]


def _solve_lower(L: jax.Array, B: jax.Array) -> jax.Array:
    return jax.lax.linalg.triangular_solve(
        L, B, left_side=True, lower=True, transpose_a=False, unit_diagonal=False
    )


def trsm_dense(L: jax.Array, B: jax.Array) -> jax.Array:
    """Baseline: full dense TRSM, no sparsity utilization (paper §3.1)."""
    return _solve_lower(L, B)


def trsm_rhs_split(L: jax.Array, B: jax.Array, meta: SteppedMeta) -> jax.Array:
    """RHS splitting (paper Fig. 3a).

    For each RHS column block the rows above its smallest column pivot are
    zero and — because forward substitution only propagates *downward* —
    remain zero in the solution. So block ``c`` is solved against only the
    trailing subfactor ``L[s_c:, s_c:]``.
    """
    if B.shape != (meta.n, meta.m):
        raise ValueError(f"B shape {B.shape} != meta ({meta.n},{meta.m})")
    Y = jnp.zeros_like(B)
    for c in range(meta.num_col_blocks):
        c0, c1 = meta.col_block(c)
        s = int(meta.col_starts[c])
        if s >= meta.n:  # all-zero column block: solution stays zero
            continue
        sol = _solve_lower(L[s:, s:], B[s:, c0:c1])
        Y = Y.at[s:, c0:c1].set(sol)
    return Y


def trsm_factor_split(
    L: jax.Array,
    B: jax.Array,
    meta: SteppedMeta,
    block_mask: Optional[np.ndarray] = None,
) -> jax.Array:
    """Factor splitting with optional pruning (paper Fig. 3b).

    Blocked forward substitution. At factor block-row ``k`` only the leading
    ``widths[k]`` RHS columns can be nonzero; the diagonal TRSM and the GEMM
    update of the rows below are restricted to them. If ``block_mask`` (the
    lower-triangular block fill pattern of ``L``) is given, GEMM updates for
    structurally-zero factor blocks are skipped entirely — the TPU-native
    form of the paper's row pruning.
    """
    if B.shape != (meta.n, meta.m):
        raise ValueError(f"B shape {B.shape} != meta ({meta.n},{meta.m})")
    nb = meta.num_row_blocks
    if block_mask is not None:
        block_mask = np.asarray(block_mask)
        if block_mask.shape != (nb, nb):
            raise ValueError(f"block_mask shape {block_mask.shape} != ({nb},{nb})")
    Y = B
    n = meta.n
    for k in range(nb):
        r0, r1 = meta.row_block(k)
        w = int(meta.widths[k])
        if w == 0:
            continue
        Yk = _solve_lower(L[r0:r1, r0:r1], Y[r0:r1, :w])
        Y = Y.at[r0:r1, :w].set(Yk)
        if r1 >= n:
            continue
        if block_mask is None:
            Y = Y.at[r1:, :w].add(-(L[r1:, r0:r1] @ Yk))
        else:
            # Pruning: touch only structurally nonzero subdiagonal blocks.
            for i in range(k + 1, nb):
                if not block_mask[i, k]:
                    continue
                i0, i1 = meta.row_block(i)
                Y = Y.at[i0:i1, :w].add(-(L[i0:i1, r0:r1] @ Yk))
    return Y


def trsm_factor_split_packed(L, B: jax.Array, meta: SteppedMeta) -> jax.Array:
    """Factor splitting on a PACKED factor (repro.sparse.packed).

    Same blocked forward substitution as :func:`trsm_factor_split`, but the
    factor blocks are gathered from the packed value stack instead of sliced
    out of a dense (n, n) array — pruning is inherent: blocks absent from
    the packed layout simply do not exist. Ragged last blocks are handled by
    static slicing of the (identity-padded) stored tiles, so results match
    the dense-masked path bit-for-bit.
    """
    from repro.sparse.packed import PackedBlocks

    if not isinstance(L, PackedBlocks):
        raise TypeError("trsm_factor_split_packed expects a PackedBlocks "
                        f"factor, got {type(L).__name__}")
    index = L.index
    vals = L.values
    if B.shape != (meta.n, meta.m):
        raise ValueError(f"B shape {B.shape} != meta ({meta.n},{meta.m})")
    if (index.bs, index.n) != (meta.block_size, meta.n):
        raise ValueError(
            f"packed index (n={index.n}, bs={index.bs}) does not match "
            f"stepped meta (n={meta.n}, bs={meta.block_size})")
    nb = meta.num_row_blocks
    Y = B
    n = meta.n
    for k in range(nb):
        r0, r1 = meta.row_block(k)
        b = r1 - r0
        w = int(meta.widths[k])
        if w == 0:
            continue
        Lkk = vals[index.slot(k, k)][:b, :b]
        Yk = _solve_lower(Lkk, Y[r0:r1, :w])
        Y = Y.at[r0:r1, :w].set(Yk)
        if r1 >= n:
            continue
        for i, s in index.col_slots(k):
            i0, i1 = meta.row_block(i)
            Y = Y.at[i0:i1, :w].add(-(vals[s][: i1 - i0, :b] @ Yk))
    return Y
