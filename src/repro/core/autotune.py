"""Assembly autotuner: pick the SC-assembly plan the paper picks by hand.

The paper's central empirical result (Table 1, Figs. 5-6) is that the best
TRSM/SYRK splitting variant AND the best block size depend on the input
sparsity pattern — the authors choose them per machine and per mesh. This
module turns that manual choice into a planner:

  1. **Enumerate** the full ``SchurAssemblyConfig`` design space: 3 TRSM
     variants x 3 SYRK variants x candidate block sizes x pruning on/off x
     Pallas kernels on/off (structural duplicates are canonicalized away —
     e.g. ``prune`` only distinguishes ``factor_split`` TRSM).
  2. **Score** every candidate with the existing FLOP model
     (:func:`repro.core.schur.assembly_flops`) plus a byte-traffic and
     launch-count model (below), fed through the roofline cost model of
     :mod:`repro.launch.roofline` (``DeviceModel.time_s``).
  3. Optionally **measure** the top-k candidates (plus the dense baseline)
     with real timed micro-runs on synthetic data carrying the exact
     sparsity pattern (``measure="auto"``), and pick the fastest.
  4. **Cache** the winning plan in a content-addressed on-disk cache keyed
     by a fingerprint of the sparsity pattern + device kind, so multi-step
     simulations and repeat launches pay the search once.

See docs/autotuning.md for the cost model derivation, the cache-key
contents, and how to pin a plan for reproducibility.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.schur import (
    SYRK_VARIANTS,
    TRSM_VARIANTS,
    SchurAssemblyConfig,
    assembly_flops,
    make_assembler,
    schur_dense_baseline,
)
from repro.core.stepped import SteppedMeta, build_stepped_meta
from repro.launch.roofline import DeviceModel, detect_device

__all__ = [
    "Plan",
    "plan_assembly",
    "plan_from_builder",
    "enumerate_space",
    "assembly_cost",
    "assembly_bytes",
    "pattern_fingerprint",
    "default_block_sizes",
    "plan_cache_dir",
    "clear_plan_cache",
]

# Bump when the candidate space or the cost model changes shape: stale
# cached plans from an older search must not be served for the new one.
# v2: packed factor storage joined the space (storage= on every config).
# v3: the assembly stage joined the cache key ("dual" | "dirichlet" —
#     the primal boundary Schur stage of the Dirichlet preconditioner is
#     planned and cached independently of the dual-operator stage).
# v4: the fused TRSM→SYRK megakernel joined the space (fused= on every
#     config), and multi-stage graphs are planned JOINTLY under one cache
#     key over all stages (repro.core.stages) instead of per-stage entries.
SPACE_VERSION = 4

# Pallas kernels only run natively on TPU; elsewhere they fall back to
# interpret mode, which is orders of magnitude slower. The model multiplies
# pallas-candidate times by this on non-TPU devices so they are enumerated
# (full design space) but never win off-TPU.
_INTERPRET_PENALTY = 200.0

_F64 = 8  # assembly dtype bytes (the FETI substrate runs f64)


# --------------------------------------------------------------------------
# byte-traffic + launch-count model (complements SteppedMeta's FLOP model)
# --------------------------------------------------------------------------

def _packed_blocks(meta: SteppedMeta,
                   block_mask: Optional[np.ndarray]) -> int:
    """Stored factor blocks under packed storage: the fill mask's nnz, or
    the full lower triangle when no symbolic mask is available."""
    nb = meta.num_row_blocks
    if block_mask is None:
        return nb * (nb + 1) // 2
    return int(np.tril(np.asarray(block_mask)).sum())


def _trsm_bytes_ops(meta: SteppedMeta, cfg: SchurAssemblyConfig,
                    block_mask: Optional[np.ndarray], db: int
                    ) -> Tuple[float, int]:
    n, m = meta.n, meta.m
    packed = cfg.storage == "packed"
    if cfg.use_pallas and cfg.trsm_variant != "dense":
        # single fused launch; streams the factor (packed: only the stored
        # blocks + the SMEM block index), Linv and B/Y once
        bs = meta.block_size
        n_pad = meta.num_row_blocks * bs
        m_pad = meta.num_col_blocks * meta.rhs_block_size
        if packed:
            factor = _packed_blocks(meta, block_mask) * bs * bs
        else:
            factor = n_pad * n_pad / 2
        return db * (factor + n_pad * bs + 2 * n_pad * m_pad), 1
    if cfg.trsm_variant == "dense":
        extra = 0.0
        if packed:
            # transient densify of the packed factor before the library TRSM
            extra = _packed_blocks(meta, block_mask) * meta.block_size ** 2 \
                + n * n / 2
        return db * (n * n / 2 + 2 * n * m + extra), 1 + int(packed)
    if cfg.trsm_variant == "rhs_split":
        total, ops = 0.0, 0
        if packed:  # transient densify before the per-stripe solves
            total += db * (_packed_blocks(meta, block_mask)
                           * meta.block_size ** 2 + n * n / 2)
            ops += 1
        for c in range(meta.num_col_blocks):
            c0, c1 = meta.col_block(c)
            s = int(meta.col_starts[c])
            if s >= n:
                continue
            nn = n - s
            total += db * (nn * nn / 2 + 2 * nn * (c1 - c0))
            ops += 1
        return total, ops
    # factor_split: packed storage prunes structurally (absent blocks are
    # never addressed), so it always takes the masked accounting
    total, ops = 0.0, 0
    nb = meta.num_row_blocks
    mask = np.asarray(block_mask) \
        if ((cfg.prune or packed) and block_mask is not None) else None
    for k in range(nb):
        r0, r1 = meta.row_block(k)
        b = r1 - r0
        w = int(meta.widths[k])
        if w == 0:
            continue
        total += db * (b * b / 2 + 2 * b * w)  # diagonal TRSM
        ops += 1
        if r1 >= n:
            continue
        if mask is None:
            total += db * ((n - r1) * b + 2 * (n - r1) * w)
            ops += 1
        else:
            for i in range(k + 1, nb):
                if not mask[i, k]:
                    continue
                i0, i1 = meta.row_block(i)
                total += db * ((i1 - i0) * b + 2 * (i1 - i0) * w)
                ops += 1
    return total, ops


def _syrk_bytes_ops(meta: SteppedMeta, cfg: SchurAssemblyConfig,
                    db: int) -> Tuple[float, int]:
    n, m = meta.n, meta.m
    if cfg.use_pallas and cfg.syrk_variant != "dense":
        n_pad = meta.num_row_blocks * meta.block_size
        m_pad = meta.num_col_blocks * meta.rhs_block_size
        return db * (n_pad * m_pad + m_pad * m_pad), 1
    if cfg.syrk_variant == "dense":
        return db * (n * m + m * m), 1
    if cfg.syrk_variant == "input_split":
        total, ops = 0.0, 0
        for k in range(meta.num_row_blocks):
            r0, r1 = meta.row_block(k)
            w = int(meta.widths[k])
            if w == 0:
                continue
            # read the row block + read-modify-write the w x w accumulator:
            # this term is what penalizes small blocks for input_split
            total += db * ((r1 - r0) * w + 2 * w * w)
            ops += 1
        return total, ops
    # output_split
    total, ops = 0.0, 0
    for i in range(meta.num_col_blocks):
        i0, i1 = meta.col_block(i)
        s = int(meta.col_starts[i])
        if s >= n:
            continue
        ci = i1 - i0
        total += db * ((n - s) * ci + ci * ci)
        ops += 1
        if i0 > 0:
            total += db * ((n - s) * i0 + 2 * ci * i0)
            ops += 1
    return total, ops


def assembly_bytes(meta: SteppedMeta, cfg: SchurAssemblyConfig,
                   block_mask: Optional[np.ndarray] = None,
                   dtype_bytes: int = _F64) -> dict:
    """Estimated main-memory traffic (bytes) and dispatched-op counts."""
    if cfg.fused:
        # ONE megakernel launch: factor + Linv + B in, F out — the Y panel
        # lives in VMEM and never touches HBM (the whole point of fusing;
        # unfused pays ~2·n·m for the Y round-trip plus nc re-reads)
        db = dtype_bytes
        bs = meta.block_size
        n_pad = meta.num_row_blocks * bs
        m_pad = meta.num_col_blocks * meta.rhs_block_size
        if cfg.storage == "packed":
            factor = _packed_blocks(meta, block_mask) * bs * bs
        else:
            factor = n_pad * n_pad / 2
        total = db * (factor + n_pad * bs + n_pad * m_pad + m_pad * m_pad)
        # attribute it all to "trsm" so the roofline sums stay well-formed
        return {"trsm": total, "syrk": 0.0, "total": total,
                "trsm_ops": 1, "syrk_ops": 0, "ops": 1}
    tb, to = _trsm_bytes_ops(meta, cfg, block_mask, dtype_bytes)
    sb, so = _syrk_bytes_ops(meta, cfg, dtype_bytes)
    return {"trsm": tb, "syrk": sb, "total": tb + sb,
            "trsm_ops": to, "syrk_ops": so, "ops": to + so}


def assembly_cost(meta: SteppedMeta, cfg: SchurAssemblyConfig,
                  device: DeviceModel,
                  block_mask: Optional[np.ndarray] = None,
                  dtype_bytes: int = _F64) -> dict:
    """Roofline time estimate of one assembly under ``cfg`` on ``device``.

    FLOPs come from the paper-validated model (:func:`assembly_flops`);
    bytes and launch counts from :func:`assembly_bytes`; both are combined
    by ``DeviceModel.time_s``. Pallas candidates off-TPU get the interpret
    penalty (they are enumerated, but cannot win).
    """
    fl = assembly_flops(meta, cfg)
    by = assembly_bytes(meta, cfg, block_mask, dtype_bytes)
    trsm_s = device.time_s(fl["trsm"], by["trsm"], by["trsm_ops"])
    syrk_s = device.time_s(fl["syrk"], by["syrk"], by["syrk_ops"])
    total = trsm_s + syrk_s
    if cfg.use_pallas and device.kind != "tpu":
        total *= _INTERPRET_PENALTY
    return {"trsm_s": trsm_s, "syrk_s": syrk_s, "total_s": total,
            "flops": fl["total"], "bytes": by["total"], "ops": by["ops"]}


# --------------------------------------------------------------------------
# candidate enumeration
# --------------------------------------------------------------------------

def default_block_sizes(n: int) -> Tuple[int, ...]:
    """Candidate factor block sizes for an n-row factor: powers of two in
    the paper's sweep range (Fig. 5 sweeps ~100..2000; MXU wants 128-ish),
    clipped to the problem size."""
    cands = [b for b in (8, 16, 32, 64, 128, 256) if b <= n]
    return tuple(cands) if cands else (max(1, n),)


def enumerate_space(block_sizes: Sequence[int],
                    interpret: bool = False,
                    storage: Optional[str] = None
                    ) -> list[SchurAssemblyConfig]:
    """The full Table-1 design space, canonicalized — now including the
    factor storage layout.

    3 TRSM x 3 SYRK x |block_sizes| x prune on/off x pallas on/off x
    storage, minus structural duplicates: ``prune`` only affects non-pallas
    ``factor_split`` TRSM, ``use_pallas`` is an identity when both variants
    are "dense" (the pallas kernels only cover split variants), and packed
    storage is only enumerated where it is native (``factor_split`` TRSM
    and the Pallas kernels — elsewhere it densifies transiently and can
    never beat its dense twin). ``storage`` restricts the space to one
    layout ("dense"/"packed"); ``None`` enumerates both.

    The fused TRSM→SYRK megakernel (SPACE_VERSION 4) adds one candidate
    per (block size, storage): its schedule is structurally rhs-split ×
    output-split, so the variant fields are pinned to that pair (dense
    storage) / factor-split × output-split (packed storage, where the
    factor arrives as the CSR block stack) and ``fused=True`` marks it as
    its own measured-refinement family.
    """
    if storage not in (None, "dense", "packed"):
        raise ValueError(f"storage must be None|dense|packed, got {storage!r}")
    want = ("dense", "packed") if storage is None else (storage,)
    out = []
    for bs in block_sizes:
        for tv in TRSM_VARIANTS:
            for sv in SYRK_VARIANTS:
                if "dense" in want:
                    prunes = (False, True) if tv == "factor_split" \
                        else (False,)
                    for prune in prunes:
                        out.append(SchurAssemblyConfig(
                            trsm_variant=tv, syrk_variant=sv, block_size=bs,
                            prune=prune, use_pallas=False, storage="dense"))
                if "packed" in want and tv == "factor_split":
                    out.append(SchurAssemblyConfig(
                        trsm_variant=tv, syrk_variant=sv, block_size=bs,
                        prune=True, use_pallas=False, storage="packed"))
                if tv == "dense" and sv == "dense":
                    continue
                if "dense" in want:
                    out.append(SchurAssemblyConfig(
                        trsm_variant=tv, syrk_variant=sv, block_size=bs,
                        prune=False, use_pallas=True, interpret=interpret,
                        storage="dense"))
                if "packed" in want and tv == "factor_split":
                    out.append(SchurAssemblyConfig(
                        trsm_variant=tv, syrk_variant=sv, block_size=bs,
                        prune=False, use_pallas=True, interpret=interpret,
                        storage="packed"))
        # the fused megakernel: one candidate per storage layout
        if "dense" in want:
            out.append(SchurAssemblyConfig(
                trsm_variant="rhs_split", syrk_variant="output_split",
                block_size=bs, prune=False, use_pallas=True, fused=True,
                interpret=interpret, storage="dense"))
        if "packed" in want:
            out.append(SchurAssemblyConfig(
                trsm_variant="factor_split", syrk_variant="output_split",
                block_size=bs, prune=False, use_pallas=True, fused=True,
                interpret=interpret, storage="packed"))
    if not out:
        # storage="packed" with no native candidate shape cannot happen
        # (factor_split is always enumerated), but guard anyway
        raise ValueError("empty candidate space")
    return out


# --------------------------------------------------------------------------
# content-addressed plan cache
# --------------------------------------------------------------------------

def plan_cache_dir() -> str:
    """Cache root: ``$REPRO_PLAN_CACHE_DIR`` (canonical; what CI sets for
    hermetic per-job caches), falling back to the legacy
    ``$REPRO_PLAN_CACHE`` spelling, then ``~/.cache/repro/plans``.

    Read at every cache access — not captured at import — so tests and CI
    can point the planner at a temp dir without reloading the module."""
    root = os.environ.get("REPRO_PLAN_CACHE_DIR") \
        or os.environ.get("REPRO_PLAN_CACHE")
    if not root:
        root = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "plans")
    return root


def clear_plan_cache() -> int:
    """Delete every cached plan; returns the number removed."""
    root = plan_cache_dir()
    if not os.path.isdir(root):
        return 0
    removed = 0
    for fn in os.listdir(root):
        if fn.endswith(".json"):
            os.remove(os.path.join(root, fn))
            removed += 1
    return removed


def pattern_fingerprint(pivots: np.ndarray, n: int, m: int,
                        extra: Sequence[np.ndarray] = ()) -> str:
    """Content hash of what the cost model can see of a sparsity pattern.

    The stepped pipeline's cost is fully determined by the column pivots
    (plus factor structure, passed via ``extra`` when pruning matters) —
    two B-transpose patterns with identical pivots assemble identically, so
    they deliberately share a plan-cache entry.
    """
    h = hashlib.sha256()
    h.update(f"{n}:{m}:".encode())
    h.update(np.ascontiguousarray(pivots, dtype=np.int64).tobytes())
    for a in extra:
        h.update(b"|")
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def _cache_key(fingerprint: str, device: DeviceModel,
               block_sizes: Sequence[int], measured: bool,
               storage: Optional[str] = None,
               stage: str = "dual") -> str:
    # `measured` is part of the key: a model-only plan must never be served
    # to a measure="auto" caller (it would silently skip the measured
    # refinement and its never-slower-than-dense guarantee), nor vice versa.
    # `storage` restrictions likewise search a different space, and `stage`
    # separates the dual-operator assembly from the Dirichlet primal Schur
    # assembly even if their pattern fingerprints ever collided.
    h = hashlib.sha256()
    h.update(f"v{SPACE_VERSION}:{device.kind}:{stage}:{fingerprint}:"
             f"{int(measured)}:{storage or 'any'}:".encode())
    h.update(",".join(str(b) for b in sorted(block_sizes)).encode())
    return h.hexdigest()


# --------------------------------------------------------------------------
# the plan
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    """A chosen assembly configuration plus its cost accounting.

    ``predicted_s`` is the roofline-model estimate, ``measured_s`` the
    median timed micro-run (None when ``measure="never"`` or on cache
    hits from model-only searches). ``baseline_*`` are the same numbers
    for the dense baseline of [9] for speedup reporting.
    """

    cfg: SchurAssemblyConfig
    predicted_s: float
    measured_s: Optional[float]
    baseline_predicted_s: float
    baseline_measured_s: Optional[float]
    device: str
    key: str
    candidates: int
    from_cache: bool = False

    @property
    def predicted_speedup(self) -> float:
        return self.baseline_predicted_s / max(self.predicted_s, 1e-30)

    @property
    def measured_speedup(self) -> Optional[float]:
        if self.measured_s is None or self.baseline_measured_s is None:
            return None
        return self.baseline_measured_s / max(self.measured_s, 1e-30)

    def summary(self) -> str:
        c = self.cfg
        lines = [
            f"plan[{self.device}] trsm={c.trsm_variant} "
            f"syrk={c.syrk_variant} block={c.block_size} "
            f"rhs_block={c.rhs_bs} prune={c.prune} pallas={c.use_pallas} "
            f"storage={c.storage}"
            f"{' (cached)' if self.from_cache else ''}",
            f"  predicted {self.predicted_s * 1e6:9.1f}us  "
            f"(dense baseline {self.baseline_predicted_s * 1e6:.1f}us, "
            f"{self.predicted_speedup:.2f}x) over "
            f"{self.candidates} candidates",
        ]
        if self.measured_s is not None:
            base = ("" if self.baseline_measured_s is None else
                    f"  (dense baseline {self.baseline_measured_s * 1e6:.1f}"
                    f"us, {self.measured_speedup:.2f}x)")
            lines.append(
                f"  measured  {self.measured_s * 1e6:9.1f}us{base}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["cfg"] = dataclasses.asdict(self.cfg)
        d.pop("from_cache")
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        d = dict(d)
        d["cfg"] = SchurAssemblyConfig(**d["cfg"])
        return cls(**d, from_cache=True)


def _load_cached(key: str) -> Optional[Plan]:
    path = os.path.join(plan_cache_dir(), key + ".json")
    try:
        with open(path) as f:
            return Plan.from_json(json.load(f))
    except (OSError, ValueError, TypeError, KeyError):
        return None


def _store(plan: Plan) -> None:
    root = plan_cache_dir()
    try:
        os.makedirs(root, exist_ok=True)
        tmp = os.path.join(root, f".{plan.key}.tmp.{os.getpid()}")
        with open(tmp, "w") as f:
            json.dump(plan.to_json(), f, indent=1)
        os.replace(tmp, os.path.join(root, plan.key + ".json"))
    except OSError:
        pass  # cache is best-effort; planning correctness never depends on it


# --------------------------------------------------------------------------
# timed micro-runs
# --------------------------------------------------------------------------

def _synthesize_inputs(meta: SteppedMeta, seed: int = 0):
    """Timing probes with the exact sparsity pattern; values are never
    consumed numerically, only their shapes/pattern drive the schedule."""
    rng = np.random.default_rng(seed)
    n, m = meta.n, meta.m
    L = np.tril(rng.standard_normal((n, n))) * 0.05
    np.fill_diagonal(L, 1.0 + rng.random(n))
    piv_orig = meta.pivots[meta.inv_perm]
    Bt = np.zeros((n, m))
    cols = np.flatnonzero(piv_orig < n)
    Bt[piv_orig[cols], cols] = rng.choice([-1.0, 1.0], size=len(cols))
    return L, Bt


def _time_best(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Min-of-reps wall time: the minimum is the standard microbenchmark
    estimator under one-sided interference noise (shared containers)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# --------------------------------------------------------------------------
# the planner
# --------------------------------------------------------------------------

MetaBuilder = Callable[
    [int, int], Tuple[SteppedMeta, Optional[np.ndarray]]
]  # (block_size, rhs_block_size) -> (meta, block_mask)


def plan_from_builder(
    meta_builder: MetaBuilder,
    fingerprint: str,
    *,
    block_sizes: Optional[Sequence[int]] = None,
    n_hint: Optional[int] = None,
    measure: str = "auto",
    top_k: int = 8,
    device: Optional[DeviceModel] = None,
    cache: bool = True,
    reps: int = 5,
    storage: Optional[str] = None,
    stage: str = "dual",
) -> Plan:
    """Core search: builder-parameterized so the cluster path can score the
    true *envelope* metadata it will execute with (see feti.assembly).

    ``measure``: "auto" refines the model's top-k with timed micro-runs
    ("never"/"model" skips them — pure roofline ranking). Pallas candidates
    are measured only on TPU (interpret timing is meaningless).

    ``storage`` restricts the search to one factor layout ("dense" |
    "packed"); ``None`` searches both and the winning plan's
    ``cfg.storage`` records the choice.

    ``stage`` names which assembly the plan is for — "dual" (the B̃ᵀ-RHS
    dual-operator SC) or "dirichlet" (the K_ib-RHS primal boundary Schur
    of :mod:`repro.feti.dirichlet`). It only enters the cache key: the
    candidate space and cost model are shared, the sparsity inputs differ.
    """
    if measure not in ("auto", "never", "model"):
        raise ValueError(f"measure must be auto|never|model, got {measure!r}")
    device = device or detect_device()

    probe_meta, _ = meta_builder(8, 8) if n_hint is None else (None, None)
    n = n_hint if n_hint is not None else probe_meta.n
    if block_sizes is None:
        block_sizes = default_block_sizes(n)

    key = _cache_key(fingerprint, device, block_sizes,
                     measured=(measure == "auto"), storage=storage,
                     stage=stage)
    if cache:
        hit = _load_cached(key)
        if hit is not None:
            return hit

    interpret = device.kind != "tpu"
    candidates = enumerate_space(block_sizes, interpret=interpret,
                                 storage=storage)

    # score every candidate with the roofline model; metas/masks are shared
    # per (block_size, rhs_block_size) so the builder runs once per size
    built: dict[tuple, tuple] = {}
    scored = []
    for cfg in candidates:
        bk = (cfg.block_size, cfg.rhs_bs)
        if bk not in built:
            built[bk] = meta_builder(*bk)
        meta, mask = built[bk]
        cost = assembly_cost(meta, cfg, device, block_mask=mask)
        scored.append((cost["total_s"], cfg, meta, mask))
    scored.sort(key=lambda t: t[0])

    dense_cfg = SchurAssemblyConfig(
        trsm_variant="dense", syrk_variant="dense",
        block_size=min(block_sizes), prune=False, storage="dense")
    bk = (dense_cfg.block_size, dense_cfg.rhs_bs)
    if bk not in built:
        built[bk] = meta_builder(*bk)
    dense_meta, dense_mask = built[bk]
    baseline_pred = assembly_cost(
        dense_meta, dense_cfg, device, block_mask=dense_mask)["total_s"]

    best_s, best_cfg, best_meta, best_mask = scored[0]
    measured_s = baseline_meas = None

    if measure == "auto":
        import jax
        import jax.numpy as jnp

        Lh, Bth = _synthesize_inputs(dense_meta)
        L = jnp.asarray(Lh)
        Bt = jnp.asarray(Bth)
        # throwaway run first: spins up BLAS threads / clock governors so
        # whichever candidate happens to be timed first isn't penalized
        jax.block_until_ready(schur_dense_baseline(L, Bt))
        baseline_meas = _time_best(
            jax.jit(schur_dense_baseline), L, Bt, reps=reps)

        def _measure(t):
            _, cfg, meta, mask = t
            if cfg.is_dense_baseline and cfg.storage == "dense":
                # byte-identical program to schur_dense_baseline (the
                # permutation-skip fast path) — reuse its timing
                return baseline_meas
            Lrun = L
            if cfg.storage == "packed":
                # packing happens once in preprocessing, so it is kept out
                # of the timed region — the assembler sees the packed stack
                from repro.sparse.packed import (
                    pack_factor,
                    packed_block_index_for,
                )

                index = packed_block_index_for(mask, meta.n, cfg.block_size)
                Lrun = jax.block_until_ready(pack_factor(L, index))
            assembler = jax.jit(make_assembler(meta, cfg, mask))
            return _time_best(assembler, Lrun, Bt, reps=reps)

        # Two-stage measured refinement. The roofline model is only trusted
        # to rank candidates WITHIN a variant family (it can misjudge a
        # whole family's library/backend constant), so:
        #   stage 1 — time the model-best candidate of every (trsm, syrk)
        #             pair; dense/dense is one of them, so the chosen plan
        #             can never be slower than the baseline it reports;
        #   stage 2 — sweep the winning pair across its remaining block
        #             sizes / prune toggles (the Fig. 5 axis), bounded by
        #             top_k.
        # family key: the fused megakernel is its own family, so whenever
        # pallas candidates are runnable (on TPU) fused is always timed
        # against unfused — "never slower than unfused" holds by
        # construction of this refinement, not by trusting the model
        def _family(cfg):
            return (cfg.trsm_variant, cfg.syrk_variant, cfg.storage,
                    cfg.fused)

        runnable = [t for t in scored
                    if not (t[1].use_pallas and device.kind != "tpu")]
        stage1: dict = {}
        for t in runnable:  # runnable is model-score sorted
            stage1.setdefault(_family(t[1]), t)
        results = [(_measure(t), t) for t in stage1.values()]
        _, win = min(results, key=lambda r: r[0])
        win_pair = _family(win[1])
        stage2 = [t for t in runnable
                  if _family(t[1]) == win_pair
                  and t is not stage1[win_pair]][:top_k]
        results += [(_measure(t), t) for t in stage2]

        best_meas, (best_s, best_cfg, best_meta, best_mask) = \
            min(results, key=lambda r: r[0])
        measured_s = best_meas
        if baseline_meas < best_meas and storage != "packed":
            # noise guard: never ship a plan measured slower than dense
            # (unless the caller pinned packed storage — then the layout
            # is a requirement, not a candidate)
            best_s, best_cfg = baseline_pred, dense_cfg
            measured_s = baseline_meas

    plan = Plan(
        cfg=best_cfg,
        predicted_s=float(best_s),
        measured_s=measured_s,
        baseline_predicted_s=float(baseline_pred),
        baseline_measured_s=baseline_meas,
        device=device.kind,
        key=key,
        candidates=len(candidates),
    )
    if cache:
        _store(plan)
    return plan


def plan_assembly(
    pattern: np.ndarray,
    *,
    factor_pattern: Optional[np.ndarray] = None,
    block_sizes: Optional[Sequence[int]] = None,
    measure: str = "auto",
    top_k: int = 8,
    device: Optional[DeviceModel] = None,
    cache: bool = True,
    storage: Optional[str] = None,
) -> Plan:
    """Plan the SC assembly for one B-transpose sparsity ``pattern``.

    Args:
      pattern: (n, m) boolean-ish sparsity pattern of B-transpose in factor
        row order / original column order (what :func:`build_stepped_meta`
        takes).
      factor_pattern: optional (n, n) sparsity pattern of the (permuted)
        stiffness matrix; enables scoring of the pruning toggle via the
        symbolic block fill mask at each candidate block size.
      block_sizes / measure / top_k / device / cache: see
        :func:`plan_from_builder`.
    """
    pattern = np.asarray(pattern) != 0
    n, m = pattern.shape

    def builder(bs: int, rbs: int):
        meta = build_stepped_meta(pattern, block_size=bs, rhs_block_size=rbs)
        mask = None
        if factor_pattern is not None:
            from repro.sparse import block_pattern, block_symbolic_cholesky

            mask = block_symbolic_cholesky(
                block_pattern(factor_pattern, bs))
        return meta, mask

    from repro.core.stepped import column_pivots

    extra = []
    if factor_pattern is not None:
        # cheap factor-structure summary: per-row nonzero counts
        extra.append(np.asarray(factor_pattern != 0).sum(axis=1)
                     .astype(np.int64))
    fp = pattern_fingerprint(column_pivots(pattern), n, m, extra=extra)
    return plan_from_builder(
        builder, fp, block_sizes=block_sizes, n_hint=n, measure=measure,
        top_k=top_k, device=device, cache=cache, storage=storage)
