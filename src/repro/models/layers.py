"""Shared neural building blocks: norms, MLPs, embeddings, RoPE/M-RoPE.

Pure-functional: params are nested dicts of jnp arrays; init functions take
a PRNG key and return the dict. Activation sharding hints go through
``repro.distributed.sharding.shard_act`` (a no-op without a mesh).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "layer_norm",
    "init_norm",
    "apply_norm",
    "init_dense",
    "dense",
    "init_mlp",
    "mlp",
    "rope_frequencies",
    "apply_rope",
    "apply_mrope",
]


# ---------------------------------------------------------------- norms ----
def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(params: dict, x, kind: str, eps: float):
    if kind == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], eps)
    return rms_norm(x, params["scale"], eps)


# ---------------------------------------------------------------- dense ----
def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: dict, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ------------------------------------------------------------------ MLP ----
def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype,
             bias: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    p = {}
    if kind in ("swiglu", "geglu"):
        p["wi"] = init_dense(ks[0], d_model, d_ff, dtype, bias)
        p["wg"] = init_dense(ks[1], d_model, d_ff, dtype, bias)
    else:
        p["wi"] = init_dense(ks[0], d_model, d_ff, dtype, bias)
    p["wo"] = init_dense(ks[2], d_ff, d_model, dtype, bias)
    return p


def mlp(params: dict, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(dense(params["wg"], x)) * dense(params["wi"], x)
    elif kind == "geglu":
        h = jax.nn.gelu(dense(params["wg"], x)) * dense(params["wi"], x)
    elif kind == "squared_relu":  # nemotron-4
        h = jnp.square(jax.nn.relu(dense(params["wi"], x)))
    elif kind == "gelu":
        h = jax.nn.gelu(dense(params["wi"], x))
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return dense(params["wo"], h)


# ----------------------------------------------------------------- RoPE ----
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    inv = rope_frequencies(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections: Tuple[int, ...]):
    """Multimodal RoPE (Qwen2-VL): the head_dim/2 frequency slots are split
    into (t, h, w) sections, each rotated by its own position stream.

    x: (B, S, H, D); positions: (B, S, 3) int32 (t, h, w indices; equal for
    text tokens).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_frequencies(x.shape[-1], theta)  # (half,)
    # section id per frequency slot
    sec_edges = []
    acc = 0
    for s in sections:
        sec_edges.append((acc, acc + s))
        acc += s
    ang_parts = []
    for i, (lo, hi) in enumerate(sec_edges):
        pos_i = positions[..., i].astype(jnp.float32)  # (B, S)
        ang_parts.append(pos_i[..., None] * inv[lo:hi])
    ang = jnp.concatenate(ang_parts, axis=-1)  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
