"""Mixture-of-Experts layer: GShard-style grouped top-k dispatch.

Capacity-based dispatch/combine einsums shard cleanly under GSPMD: tokens
group over the batch ('data' axis), experts over the 'model' axis (EP).
Shared experts (DeepSeek-V2) run densely for every token. The router adds
the standard load-balancing auxiliary loss.

The capacity-pruned expert GEMM (tokens beyond capacity are dropped) is the
MoE cousin of the paper's block-granular zero skipping: compute is bounded
by a static envelope chosen from the expected distribution, not the worst
case.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_mlp, mlp

__all__ = ["init_moe", "moe_block"]


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    e_ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    scale = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, cfg.num_experts), jnp.float32)
                   * scale).astype(jnp.float32),  # router stays fp32
        # stacked expert weights: (E, d, ff) / (E, ff, d)
        "wi": (jax.random.normal(ks[1], (cfg.num_experts, d, e_ff), jnp.float32)
               * scale).astype(dtype),
        "wg": (jax.random.normal(jax.random.fold_in(ks[1], 1),
                                 (cfg.num_experts, d, e_ff), jnp.float32)
               * scale).astype(dtype),
        "wo": (jax.random.normal(ks[2], (cfg.num_experts, e_ff, d), jnp.float32)
               * (e_ff ** -0.5)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(
            jax.random.fold_in(key, 7), d,
            e_ff * cfg.num_shared_experts, "swiglu", dtype,
        )
    return p


def moe_block(params: dict, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (y, aux_loss). Groups = batch rows."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    capacity = int(S * k / E * cfg.capacity_factor)
    capacity = max(capacity, 1)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)

    # --- top-k gating with per-expert capacity (GShard) ---
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    if cfg.moe_impl == "sort":
        return _moe_sorted(params, cfg, x, probs, gate_vals, gate_idx,
                           capacity)

    # position of each (token, choice) in its expert's queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # (B,S,k,E)
    flat = onehot.reshape(B, S * k, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # (B,S*k,E)
    pos = (pos_in_expert * flat).sum(-1).reshape(B, S, k)  # (B,S,k)
    within = pos < capacity

    # dispatch: (B,S,E,C) one-hot; combine carries the gate values
    pos_oh = jax.nn.one_hot(jnp.where(within, pos, capacity), capacity,
                            dtype=x.dtype)  # (B,S,k,C); overflow -> all-zero
    exp_oh = jax.nn.one_hot(gate_idx, E, dtype=x.dtype)  # (B,S,k,E)
    dispatch = jnp.einsum("bske,bskc->bsec", exp_oh, pos_oh)
    combine = jnp.einsum("bsk,bske,bskc->bsec",
                         gate_vals.astype(x.dtype), exp_oh, pos_oh)

    from repro.distributed.actsharding import shard_act

    dispatch = shard_act(dispatch, "dp", None, "model", None)
    combine = shard_act(combine, "dp", None, "model", None)
    xe = jnp.einsum("bsd,bsec->becd", x, dispatch)  # (B,E,C,d)
    xe = shard_act(xe, "dp", "model", None, None)  # tokens to their experts
    h = jnp.einsum("becd,edf->becf", xe, params["wi"])
    g = jnp.einsum("becd,edf->becf", xe, params["wg"])
    h = jax.nn.silu(g) * h
    h = shard_act(h, "dp", "model", None, None)
    ye = jnp.einsum("becf,efd->becd", h, params["wo"])
    y = jnp.einsum("becd,bsec->bsd", ye, combine)
    y = shard_act(y, "dp", None, None)

    if cfg.num_shared_experts:
        y = y + mlp(params["shared"], x, "swiglu")

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    aux = _aux_loss(cfg, probs, gate_idx)
    return y, aux


def _aux_loss(cfg, probs, gate_idx):
    E = cfg.num_experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef


def _moe_sorted(params, cfg, x, probs, gate_vals, gate_idx, capacity):
    """Sort/gather dispatch (MegaBlocks-style), per batch group.

    Replaces the GShard one-hot dispatch/combine einsums — 4·E·C·d flops
    per token, which for deepseek-v2 *exceeds the expert matmuls* — with
    an argsort + gathers (O(T·k·log) compares, no MXU work). Semantics
    match the GShard path: per-group expert capacity, overflow dropped,
    same gate normalization; outputs differ only in which over-capacity
    duplicates drop (queue order: sorted vs positional).

    Shards like the einsum path: groups (batch rows) over DP, experts over
    EP — the sort is within-group, so no cross-shard traffic is added.
    """
    from repro.distributed.actsharding import shard_act

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = capacity

    def one_group(xg, gv, gi):
        # xg: (S, d); gv/gi: (S, k)
        flat_e = gi.reshape(-1)  # (S*k,)
        flat_tok = jnp.repeat(jnp.arange(S), k)
        flat_gate = gv.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        tok_sorted = flat_tok[order]
        gate_sorted = flat_gate[order]
        # position within the expert's queue
        start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
        pos = jnp.arange(S * k) - start[e_sorted]
        keep = pos < C
        dest = jnp.where(keep, e_sorted * C + pos, E * C)  # overflow slot
        # scatter tokens into the (E*C, d) expert buffer
        buf = jnp.zeros((E * C + 1, d), x.dtype)
        buf = buf.at[dest].set(xg[tok_sorted] *
                               keep[:, None].astype(x.dtype))
        buf = buf[:-1].reshape(E, C, d)
        # expert FFN (same stacked weights as the einsum path)
        h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
        g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, params["wo"])
        # gather back + weighted scatter-add to token order
        ye_flat = jnp.concatenate(
            [ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)])
        contrib = ye_flat[dest] * (gate_sorted * keep)[:, None].astype(ye.dtype)
        out = jnp.zeros((S, d), ye.dtype)
        return out.at[tok_sorted].add(contrib)

    y = jax.vmap(one_group)(x, gate_vals, gate_idx)
    y = shard_act(y, "dp", None, None)
    if cfg.num_shared_experts:
        y = y + mlp(params["shared"], x, "swiglu")
    return y, _aux_loss(cfg, probs, gate_idx)
