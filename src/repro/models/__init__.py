"""Composable LM stack covering the 10 assigned architectures."""
from repro.models.config import ModelConfig
from repro.models.model import (
    default_positions,
    forward,
    init_cache,
    init_model,
)

__all__ = [
    "ModelConfig",
    "default_positions",
    "forward",
    "init_cache",
    "init_model",
]
