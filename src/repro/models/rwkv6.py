"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mix with
data-dependent decay, plus squared-ReLU channel mix.

Recurrence per head (key dim D_k = value dim D_v = rwkv_head_dim):

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    o_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

with w_t ∈ (0,1) data-dependent (the Finch novelty vs RWKV-5's static w).
Training/prefill uses a *chunked* evaluation (flash-linear-attention
style): intra-chunk contributions via masked matmuls on decay-rescaled
q/k, inter-chunk state carried by a lax.scan over chunks — O(S·D²) work,
O(S/C) sequential steps, MXU-friendly. Decode keeps the (H, D, D) state
per sequence: O(1) per token — this is why rwkv6 runs the long_500k shape.

Data-dependent mixes use single low-rank adapters (one LoRA per channel
family) — the token-shift ddlerp structure of the paper with a shared
bottleneck; see DESIGN.md §2 for recorded simplifications.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense, init_dense

__all__ = ["init_rwkv6", "rwkv6_block", "init_rwkv_state"]

LORA_RANK = 32


def init_rwkv6(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    p = {
        "wr": init_dense(ks[0], d, d, dtype),
        "wk": init_dense(ks[1], d, d, dtype),
        "wv": init_dense(ks[2], d, d, dtype),
        "wg": init_dense(ks[3], d, d, dtype),
        "wo": init_dense(ks[4], d, d, dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + lora(x)))
        "w0": jnp.full((d,), -6.0, dtype),
        "w_lora_a": init_dense(ks[5], d, LORA_RANK, dtype),
        "w_lora_b": init_dense(ks[6], LORA_RANK, d, dtype, scale=0.01),
        # per-channel bonus u
        "u": jnp.zeros((d,), dtype),
        # token-shift mix coefficients (static part of ddlerp)
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        # channel mix
        "cm_mix": jnp.full((d,), 0.5, dtype),
        "cm_k": init_dense(ks[7], d, cfg.d_ff, dtype),
        "cm_v": init_dense(ks[8], cfg.d_ff, d, dtype),
        "cm_r": init_dense(ks[9], d, d, dtype),
    }
    return p


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), dtype),  # last token (time mix)
        "shift_cm": jnp.zeros((batch, d), dtype),  # last token (channel mix)
    }


def _token_shift(x, prev):
    """(B,S,d) -> previous-token tensor, seeded by carry ``prev`` (B,d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_chunked(r, k, v, w, u, chunk: int, S0):
    """Chunked linear-attention evaluation of the RWKV recurrence.

    r,k,v: (B, S, H, D); w: (B, S, H, D) decay in (0,1); u: (H, D).
    S0: (B, H, D, D) initial state. Returns (out (B,S,H,D), S_final).
    """
    B, S, H, D = r.shape
    while S % chunk:
        chunk -= 1
    n = S // chunk

    from repro.distributed.actsharding import shard_act

    def reshape(x):
        y = x.reshape(B, n, chunk, H, D).transpose(1, 0, 3, 2, 4)
        # keep batch on DP and heads on TP through the transpose — GSPMD
        # loses it here otherwise (45 GiB/dev of replicated temporaries)
        return shard_act(y, None, "dp", "model", None, None)

    r_, k_, v_, w_ = map(reshape, (r, k, v, w))  # (n,B,H,c,D)
    logw = jnp.log(jnp.clip(w_.astype(jnp.float32), 1e-8, 1.0))
    logw = shard_act(logw, None, "dp", "model", None, None)
    cum = jnp.cumsum(logw, axis=3)  # P_t = prod_{tau<=t} w_tau (log space)
    cum = shard_act(cum, None, "dp", "model", None, None)

    def step(Sst, inputs):
        rc, kc, vc, logwc, cumc = inputs  # (B,H,c,D)
        rf = rc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        # decay-rescaled queries/keys (within-chunk, numerically safe:
        # exponents are differences of cumsums within the chunk)
        p_prev = cumc - logwc  # P_{t-1}
        r_hat = rf * jnp.exp(p_prev)
        k_hat = kf * jnp.exp(-cumc)
        # inter-chunk: o_t += r_hat_t @ S_prev
        o = jnp.einsum("bhtd,bhde->bhte", r_hat, Sst)
        # intra-chunk: strictly-past tokens
        att = jnp.einsum("bhtd,bhsd->bhts", r_hat, k_hat)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        o = o + jnp.einsum("bhts,bhse->bhte", att, vf)
        # current token bonus: r_t diag(u) k_t^T v_t
        bonus = jnp.einsum("bhtd,hd,bhtd->bht", rf, u, kf)
        o = o + bonus[..., None] * vf
        # state update to end of chunk
        p_end = cumc[:, :, -1:, :]  # (B,H,1,D)
        k_tail = kf * jnp.exp(p_end - cumc)
        S_new = Sst * jnp.exp(p_end.squeeze(2))[..., None] + jnp.einsum(
            "bhtd,bhte->bhde", k_tail, vf
        )
        return S_new, o

    inputs = (r_, k_, v_, logw, cum)
    S_fin, outs = jax.lax.scan(step, S0.astype(jnp.float32), inputs)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)
    return out.astype(r.dtype), S_fin


def rwkv6_block(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d) — already normed by the caller
    state: Optional[dict] = None,
    chunk: int = 64,
) -> Tuple[jax.Array, Optional[dict]]:
    """Time-mix block. Returns (y, new_state). state=None => fresh zeros,
    state discarded (training); state given => carried (decode/prefill)."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    st = state or init_rwkv_state(cfg, B, x.dtype)

    prev = _token_shift(x, st["shift_tm"].astype(x.dtype))

    def mix(name):
        m = params[f"mix_{name}"]
        return x * m + prev * (1 - m)

    r = dense(params["wr"], mix("r")).reshape(B, S, H, hd)
    k = dense(params["wk"], mix("k")).reshape(B, S, H, hd)
    v = dense(params["wv"], mix("v")).reshape(B, S, H, hd)
    g = dense(params["wg"], x)
    xw = mix("w")
    w_log = params["w0"].astype(jnp.float32) + dense(
        params["w_lora_b"], jnp.tanh(dense(params["w_lora_a"], xw))
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, S, H, hd)  # data-dependent decay
    u = params["u"].astype(jnp.float32).reshape(H, hd)

    out, S_fin = _wkv_chunked(r, k, v, w, u, chunk, st["S"])
    y = dense(params["wo"], (out.reshape(B, S, d) * jax.nn.silu(g)))

    new_state = None
    if state is not None:
        new_state = {
            "S": S_fin,
            "shift_tm": x[:, -1, :],
            "shift_cm": state["shift_cm"],
        }
    return y, new_state


def rwkv6_channel_mix(
    params: dict, cfg: ModelConfig, x: jax.Array,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Squared-ReLU channel mix with token shift."""
    st = state or {"shift_cm": jnp.zeros((x.shape[0], x.shape[-1]), x.dtype)}
    prev = _token_shift(x, st["shift_cm"].astype(x.dtype))
    m = params["cm_mix"]
    xk = x * m + prev * (1 - m)
    kk = jnp.square(jax.nn.relu(dense(params["cm_k"], xk)))
    rr = jax.nn.sigmoid(dense(params["cm_r"], xk))
    y = rr * dense(params["cm_v"], kk)
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["shift_cm"] = x[:, -1, :]
    return y, new_state
