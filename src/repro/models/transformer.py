"""Block + stack: one residual block per layer kind (attn / rwkv6 / rglru),
grouped into a lax.scan over pattern cycles.

Scanning over layers is load-bearing at framework scale: a 96-layer config
lowers to one rolled loop instead of 96 inlined copies, which keeps the
dry-run compile time and HLO size sane for every assigned architecture.
Heterogeneous stacks (recurrentgemma's rglru-rglru-attn cycle, deepseek's
first dense layer) are handled as prologue / scanned-cycles / epilogue.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import rwkv6 as rwkv6_mod
from repro.models.attention import attention_block, init_attention, init_kv_cache
from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, init_mlp, init_norm, mlp
from repro.models.moe import init_moe, moe_block
from repro.models.rglru import init_rglru, init_rglru_state, rglru_block

__all__ = ["init_block", "apply_block", "init_stack", "apply_stack",
           "init_layer_cache", "StackLayout"]


# ----------------------------------------------------------- single block ----
def init_block(key, cfg: ModelConfig, kind: str, use_moe: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype)}
    if kind == "attn":
        p["inner"] = init_attention(ks[0], cfg, dtype)
    elif kind == "rwkv6":
        p["inner"] = rwkv6_mod.init_rwkv6(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["inner"] = init_rglru(ks[0], cfg, dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if kind != "rwkv6":  # rwkv6 carries its own channel mix in `inner`
        if use_moe:
            p["mlp"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                                dtype, cfg.mlp_bias)
    return p


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> dict:
    if kind == "attn":
        return init_kv_cache(cfg, batch, max_len, dtype,
                             window=cfg.local_window)
    if kind == "rwkv6":
        return rwkv6_mod.init_rwkv_state(cfg, batch, dtype)
    if kind == "rglru":
        return init_rglru_state(cfg, batch, dtype)
    raise ValueError(kind)


def apply_block(
    params: dict,
    cfg: ModelConfig,
    kind: str,
    use_moe: bool,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[dict],
    cache_index: Optional[jax.Array],
    attn_args: dict,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    from repro.distributed.actsharding import shard_act

    aux = jnp.zeros((), jnp.float32)
    # (B, S, d) between blocks: batch on DP, sequence on TP (Megatron-SP)
    x = shard_act(x, "dp", "sp", None)
    h = apply_norm(params["norm1"], x, cfg.norm, cfg.norm_eps)
    if kind == "attn":
        y, new_cache = attention_block(
            params["inner"], cfg, h, positions, cache, cache_index,
            window=cfg.local_window, **attn_args,
        )
    elif kind == "rwkv6":
        y, new_cache = rwkv6_mod.rwkv6_block(params["inner"], cfg, h, cache)
    elif kind == "rglru":
        y, new_cache = rglru_block(params["inner"], cfg, h, cache)
    else:
        raise ValueError(kind)
    x = x + y

    h = apply_norm(params["norm2"], x, cfg.norm, cfg.norm_eps)
    if kind == "rwkv6":
        y, new_cache = rwkv6_mod.rwkv6_channel_mix(params["inner"], cfg, h,
                                                   new_cache)
    elif use_moe:
        y, mo_aux = moe_block(params["mlp"], cfg, h)
        aux = aux + mo_aux
    else:
        y = mlp(params["mlp"], h, cfg.mlp_kind)
    x = x + y
    return x, new_cache, aux


# ------------------------------------------------------------- the stack ----
@dataclasses.dataclass(frozen=True)
class StackLayout:
    """How num_layers decomposes into prologue / scanned cycles / epilogue."""

    pattern: Tuple[str, ...]
    prologue: Tuple[int, ...]  # absolute layer indices
    cycles: int
    epilogue: Tuple[int, ...]

    @classmethod
    def build(cls, cfg: ModelConfig) -> "StackLayout":
        P = len(cfg.layer_pattern)
        pro = tuple(range(cfg.first_dense_layers))
        rest = cfg.num_layers - len(pro)
        cycles = rest // P
        epi_start = len(pro) + cycles * P
        return cls(
            pattern=cfg.layer_pattern,
            prologue=pro,
            cycles=cycles,
            epilogue=tuple(range(epi_start, cfg.num_layers)),
        )

    def kind_of(self, cfg: ModelConfig, layer: int) -> str:
        return cfg.layer_kinds[layer]

    def moe_of(self, cfg: ModelConfig, layer: int) -> bool:
        return cfg.is_moe and layer >= cfg.first_dense_layers


def init_stack(key, cfg: ModelConfig, dtype) -> dict:
    lay = StackLayout.build(cfg)
    P = len(lay.pattern)

    def block_at(layer):
        return init_block(jax.random.fold_in(key, layer), cfg,
                          lay.kind_of(cfg, layer), lay.moe_of(cfg, layer),
                          dtype)

    params: dict = {"prologue": [block_at(li) for li in lay.prologue]}
    body = []
    base = len(lay.prologue)
    for j in range(P):
        per_cycle = [block_at(base + c * P + j) for c in range(lay.cycles)]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_cycle)
                    if per_cycle else None)
    params["body"] = body
    params["epilogue"] = [block_at(li) for li in lay.epilogue]
    return params


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    lay = StackLayout.build(cfg)
    P = len(lay.pattern)

    def mk(li):
        return init_layer_cache(cfg, lay.kind_of(cfg, li), batch, max_len,
                                dtype)

    cache: dict = {"prologue": [mk(li) for li in lay.prologue]}
    body = []
    base = len(lay.prologue)
    for j in range(P):
        per_cycle = [mk(base + c * P + j) for c in range(lay.cycles)]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_cycle)
                    if per_cycle else None)
    cache["body"] = body
    cache["epilogue"] = [mk(li) for li in lay.epilogue]
    return cache


def apply_stack(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    attn_args: Optional[dict] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Run the full layer stack. Returns (x, new_cache, aux)."""
    lay = StackLayout.build(cfg)
    P = len(lay.pattern)
    attn_args = attn_args or {}
    aux = jnp.zeros((), jnp.float32)

    def run(x, p, kind, use_moe, c):
        def fn(xx, pp, cc):
            return apply_block(
                pp, cfg, kind, use_moe, xx, positions, cc, cache_index,
                attn_args
            )

        if remat:
            fn = jax.checkpoint(fn)
        return fn(x, p, c)

    new_cache: dict = {"prologue": [], "body": [], "epilogue": []}

    for i, li in enumerate(lay.prologue):
        c = cache["prologue"][i] if cache is not None else None
        x, nc, a = run(x, params["prologue"][i], lay.kind_of(cfg, li),
                       lay.moe_of(cfg, li), c)
        new_cache["prologue"].append(nc)
        aux = aux + a

    base = len(lay.prologue)
    if lay.cycles > 0:
        kinds = [lay.kind_of(cfg, base + j) for j in range(P)]
        moes = [lay.moe_of(cfg, base + j) for j in range(P)]

        if cache is None:

            def cycle_fn(carry, pp):
                xx, au = carry
                for j in range(P):
                    xx, _, a = run(xx, pp[j], kinds[j], moes[j], None)
                    au = au + a
                return (xx, au), None

            (x, aux), _ = jax.lax.scan(cycle_fn, (x, aux),
                                       tuple(params["body"]))
            new_cache["body"] = [None] * P
        else:

            def cycle_fn(carry, xs):
                xx, au = carry
                pp, cc = xs
                ncs = []
                for j in range(P):
                    xx, nc, a = run(xx, pp[j], kinds[j], moes[j], cc[j])
                    au = au + a
                    ncs.append(nc)
                return (xx, au), tuple(ncs)

            (x, aux), body_caches = jax.lax.scan(
                cycle_fn, (x, aux),
                (tuple(params["body"]), tuple(cache["body"])),
            )
            new_cache["body"] = list(body_caches)

    for i, li in enumerate(lay.epilogue):
        c = cache["epilogue"][i] if cache is not None else None
        x, nc, a = run(x, params["epilogue"][i], lay.kind_of(cfg, li),
                       lay.moe_of(cfg, li), c)
        new_cache["epilogue"].append(nc)
        aux = aux + a

    return x, (new_cache if cache is not None else None), aux
