"""Attention: chunked (flash-style) GQA/MHA with RoPE & M-RoPE, sliding
windows, ring-buffer KV caches, and DeepSeek-V2 MLA (compressed KV cache
with weight absorption for decode, per-chunk expansion for prefill).

The chunked softmax never materializes an (S, S) score matrix — mandatory
for the 32k-prefill and 500k-decode shapes. Its block schedule (skip work
per tile according to a mask envelope) is the same trick as the paper's
stepped SYRK; causal block *skipping* (not just masking) is applied as a
beyond-paper §Perf optimization via ``skip_masked_blocks``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope, dense, init_dense

__all__ = [
    "flash_attention",
    "init_attention",
    "attention_block",
    "init_kv_cache",
]

NEG_INF = -1e30


def _chunk(x, size, axis=1):
    """(B, S, ...) -> (B, n, size, ...) without copies beyond reshape."""
    s = x.shape[axis]
    n = s // size
    return x.reshape(x.shape[:axis] + (n, size) + x.shape[axis + 1 :])


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array,  # (B, Skv) int32
    *,
    causal: bool = True,
    window: int = 0,
    kv_valid: Optional[jax.Array] = None,  # (B, Skv) bool (cache masking)
    q_chunk: int = 512,
    kv_chunk: int = 512,
    scale: Optional[float] = None,
    skip_masked_blocks: bool = False,
) -> jax.Array:
    """Memory-efficient attention with running softmax over KV chunks.

    ``skip_masked_blocks``: with causal masking, KV chunks strictly in the
    future of a whole query chunk contribute nothing; when enabled, the
    inner loop runs only over the first ``ceil(q_hi/kv_chunk)`` chunks —
    halving prefill/train attention FLOPs. The q-chunk loop is a Python
    loop (nq is small: 4–32 for our shapes), so the per-chunk live count
    is a compile-time constant and the whole thing stays reverse-mode
    differentiable (a dynamic fori bound would not be).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    def _fit(chunk, total):  # largest divisor of total that is <= chunk
        chunk = min(chunk, total)
        while total % chunk:
            chunk -= 1
        return chunk

    q_chunk = _fit(q_chunk, Sq)
    kv_chunk = _fit(kv_chunk, Skv)
    nq, nkv = Sq // q_chunk, Skv // kv_chunk

    from repro.distributed.actsharding import shard_act

    qc = _chunk(q, q_chunk).astype(jnp.float32) * scale  # (B,nq,cq,Hq,D)
    kc = _chunk(k, kv_chunk)  # (B,nkv,ck,Hkv,D)
    vc = _chunk(v, kv_chunk)
    # Pin the chunked layouts ONCE: q by heads (divisible for Hq), k/v by
    # kv-heads where divisible, else replicated-on-model — materialized
    # here so the per-chunk loop bodies slice ONE gathered buffer instead
    # of re-gathering K/V per q chunk (64×16 GiB/layer observed without
    # this on granite prefill; §Perf).
    qc = shard_act(qc, "dp", None, None, "model", None)
    kc = shard_act(kc, "dp", None, None, "model", None)
    vc = shard_act(vc, "dp", None, None, "model", None)
    qpc = _chunk(q_pos, q_chunk)  # (B,nq,cq)
    kpc = _chunk(kv_pos, kv_chunk)
    kvc = _chunk(kv_valid, kv_chunk) if kv_valid is not None else None

    def one_q_chunk(qi: int):
        qb = jnp.moveaxis(qc[:, qi], 2, 1).reshape(B, Hkv, G, q_chunk, D)
        qp = qpc[:, qi]  # (B, cq)

        def kv_step(ki, carry):
            m, l, acc = carry
            kb = jnp.moveaxis(kc[:, ki], 2, 1)  # (B,Hkv,ck,D)
            vb = jnp.moveaxis(vc[:, ki], 2, 1)  # (B,Hkv,ck,Dv)
            kp = kpc[:, ki]  # (B, ck)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qb, kb.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            mask = jnp.ones((B, q_chunk, kv_chunk), bool)
            if causal:
                mask &= kp[:, None, :] <= qp[:, :, None]
            if window > 0:
                mask &= kp[:, None, :] > qp[:, :, None] - window
            if kvc is not None:
                mask &= kvc[:, ki][:, None, :]
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return m_new, l_new, acc_new

        init = (
            jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, q_chunk), jnp.float32),
            jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32),
        )
        if skip_masked_blocks and causal and window == 0:
            # last kv chunk that can contribute to this q chunk — STATIC
            hi = (qi + 1) * q_chunk  # q_pos < hi
            n_live = min((hi + kv_chunk - 1) // kv_chunk, nkv)
        else:
            n_live = nkv
        m, l, acc = jax.lax.fori_loop(0, n_live, kv_step, init)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.reshape(B, Hq, q_chunk, Dv)
        return jnp.moveaxis(out, 1, 2)  # (B, cq, Hq, Dv)

    outs = [one_q_chunk(qi) for qi in range(nq)]
    out = jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]
    return out.astype(q.dtype)


# ------------------------------------------------------------- GQA / MLA ----
def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    if cfg.attn_kind == "mla":
        ks = jax.random.split(key, 7)
        qh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        p = {}
        if cfg.q_lora_rank:
            p["wq_a"] = init_dense(ks[0], d, cfg.q_lora_rank, dtype)
            p["q_norm_scale"] = jnp.ones((cfg.q_lora_rank,), dtype)
            p["wq_b"] = init_dense(ks[1], cfg.q_lora_rank, cfg.num_heads * qh, dtype)
        else:
            p["wq_b"] = init_dense(ks[1], d, cfg.num_heads * qh, dtype)
        p["wkv_a"] = init_dense(
            ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype
        )
        p["kv_norm_scale"] = jnp.ones((cfg.kv_lora_rank,), dtype)
        p["wk_b"] = init_dense(
            ks[3], cfg.kv_lora_rank, cfg.num_heads * cfg.qk_nope_head_dim, dtype
        )
        p["wv_b"] = init_dense(
            ks[4], cfg.kv_lora_rank, cfg.num_heads * cfg.v_head_dim, dtype
        )
        p["wo"] = init_dense(ks[5], cfg.num_heads * cfg.v_head_dim, d, dtype)
        return p
    ks = jax.random.split(key, 4)
    hd = cfg.head_dim
    return {
        "wq": init_dense(ks[0], d, cfg.num_heads * hd, dtype, cfg.qkv_bias),
        "wk": init_dense(ks[1], d, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wv": init_dense(ks[2], d, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wo": init_dense(ks[3], cfg.num_heads * hd, d, dtype),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  window: int = 0) -> dict:
    """Per-layer cache template. Local-attention layers use a ring buffer of
    the window size (essential for long_500k); MLA caches the compressed
    c_kv + shared k_rope (576 floats/token for deepseek-v2)."""
    size = min(window, max_len) if window else max_len
    if cfg.attn_kind == "mla":
        return {
            "ckv": jnp.zeros((batch, size, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, size, cfg.qk_rope_head_dim), dtype),
            "pos": jnp.full((batch, size), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def _rope_q(cfg, x, positions):
    if cfg.pos_emb == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    if cfg.pos_emb == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    return x


def _cache_write(cache: dict, names: list[str], values: list[jax.Array],
                 positions: jax.Array, index: jax.Array, ring: bool) -> dict:
    """Write S new entries at ``index`` (ring-buffer modulo if ring)."""
    S = values[0].shape[1]
    size = cache[names[0]].shape[1]
    offs = index + jnp.arange(S, dtype=jnp.int32)
    slots = jnp.mod(offs, size) if ring else offs
    new = dict(cache)
    for nm, val in zip(names, values):
        new[nm] = cache[nm].at[:, slots].set(val.astype(cache[nm].dtype))
    new["pos"] = cache["pos"].at[:, slots].set(positions[:, :S])
    return new


def attention_block(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S) or (B, S, 3) for mrope
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,  # scalar int32 write offset
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    skip_masked_blocks: bool = False,
):
    """Returns (y, new_cache). cache=None => self-attention over x only."""
    B, S, d = x.shape
    pos_1d = positions[..., 0] if positions.ndim == 3 else positions
    ring = window > 0 and cache is not None

    if cfg.attn_kind == "mla":
        return _mla_block(
            params, cfg, x, positions, pos_1d, cache, cache_index,
            q_chunk, kv_chunk, skip_masked_blocks,
        )

    from repro.distributed.actsharding import shard_act

    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(params["wq"], x).reshape(B, S, H, hd)
    k = dense(params["wk"], x).reshape(B, S, Hkv, hd)
    v = dense(params["wv"], x).reshape(B, S, Hkv, hd)
    q = shard_act(_rope_q(cfg, q, positions), "dp", None, "model", None)
    k = shard_act(_rope_q(cfg, k, positions), "dp", None, "model", None)
    v = shard_act(v, "dp", None, "model", None)

    if cache is None:
        out = flash_attention(
            q, k, v, pos_1d, pos_1d, causal=cfg.causal, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
            skip_masked_blocks=skip_masked_blocks,
        )
        new_cache = None
    elif ring and S > 1:
        # Prefill into a ring buffer: tokens early in the prefix would be
        # overwritten before their window expires, so attend over the
        # in-context sequence directly and persist only the last W tokens.
        out = flash_attention(
            q, k, v, pos_1d, pos_1d, causal=cfg.causal, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
            skip_masked_blocks=skip_masked_blocks,
        )
        Wl = min(cache["k"].shape[1], S)
        new_cache = _cache_write(
            cache, ["k", "v"], [k[:, S - Wl :], v[:, S - Wl :]],
            pos_1d[:, S - Wl :], cache_index + (S - Wl), ring=True,
        )
    else:
        cache = _cache_write(cache, ["k", "v"], [k, v], pos_1d,
                             cache_index, ring)
        kv_valid = cache["pos"] >= 0
        out = flash_attention(
            q, cache["k"], cache["v"], pos_1d, cache["pos"],
            causal=cfg.causal, window=window, kv_valid=kv_valid,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        new_cache = cache
    y = dense(params["wo"], out.reshape(B, S, H * hd))
    return y, new_cache


def _mla_block(params, cfg, x, positions, pos_1d, cache, cache_index,
               q_chunk, kv_chunk, skip_masked_blocks):
    """DeepSeek-V2 Multi-head Latent Attention.

    Train/prefill: expand k_nope/v from the compressed c_kv (per KV chunk,
    inside flash attention's loop budget — here eagerly per call since the
    expansion is S·H·(nope+v) and chunking bounds live memory).
    Decode: weight absorption — queries are projected into the compressed
    space and attention runs directly against the (c_kv ‖ k_rope) cache.
    """
    from repro.models.layers import rms_norm

    B, S, d = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank

    if cfg.q_lora_rank:
        qa = rms_norm(dense(params["wq_a"], x), params["q_norm_scale"],
                      cfg.norm_eps)
        q = dense(params["wq_b"], qa)
    else:
        q = dense(params["wq_b"], x)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = _rope_q(cfg, q_rope, positions)

    kv = dense(params["wkv_a"], x)
    ckv = rms_norm(kv[..., :rank], params["kv_norm_scale"], cfg.norm_eps)
    krope = _rope_q(cfg, kv[..., None, rank:], positions)[:, :, 0]  # (B,S,dr)

    wk_b = params["wk_b"]["w"].reshape(rank, H, dn)
    wv_b = params["wv_b"]["w"].reshape(rank, H, dv)

    if cache is None:
        # prefill/train: expanded attention, chunked softmax bounds memory
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv, wk_b)
        v = jnp.einsum("bsr,rhd->bshd", ckv, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, dr))],
            axis=-1,
        )
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(
            qfull, k, v, pos_1d, pos_1d, causal=cfg.causal,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
            scale=1.0 / math.sqrt(dn + dr),
            skip_masked_blocks=skip_masked_blocks,
        )
        new_cache = None
    else:
        cache = _cache_write(cache, ["ckv", "krope"], [ckv, krope], pos_1d,
                             cache_index, ring=False)
        # absorption: q_nope -> compressed space (B,S,H,rank)
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b)
        q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)  # (B,S,H,rank+dr)
        kv_eff = jnp.concatenate([cache["ckv"], cache["krope"]], axis=-1)
        kv_valid = cache["pos"] >= 0
        ctx = flash_attention(
            q_eff, kv_eff[:, :, None, :], cache["ckv"][:, :, None, :],
            pos_1d, cache["pos"], causal=cfg.causal, kv_valid=kv_valid,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
            scale=1.0 / math.sqrt(dn + dr),
        )  # (B,S,H,rank)
        out = jnp.einsum("bshr,rhd->bshd", ctx, wv_b)
        new_cache = cache
    y = dense(params["wo"], out.reshape(B, S, H * dv))
    return y, new_cache
