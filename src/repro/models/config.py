"""Composable model configuration covering the 10 assigned architectures.

One dataclass; families select behaviour through the ``attn_kind`` /
``mlp_kind`` / ``layer_pattern`` fields rather than subclassing, so every
architecture flows through the same transformer stack, train/serve steps,
sharding rules and dry-run machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # ---- attention ----
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    attn_kind: str = "gqa"  # gqa | mla | none
    qkv_bias: bool = False
    causal: bool = True  # False => encoder-only (hubert)
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"  # rope | mrope | none
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl: (t, h, w) rope splits
    local_window: int = 0  # >0 => sliding-window attention

    # ---- MLA (deepseek-v2) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MLP ----
    mlp_kind: str = "swiglu"  # swiglu | squared_relu | gelu | geglu
    mlp_bias: bool = False

    # ---- MoE ----
    num_experts: int = 0  # 0 => dense MLP everywhere
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # expert hidden size (deepseek: 1536)
    first_dense_layers: int = 0  # deepseek-v2: layer 0 keeps a dense MLP
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "gshard"  # "gshard" (one-hot einsum dispatch, the
    #   classic shardable baseline) | "sort" (argsort/gather dispatch,
    #   MegaBlocks-style: removes the 4·E·C·d dispatch-einsum flops —
    #   the §Perf hillclimb winner for deepseek/grok)

    # ---- recurrent / hybrid ----
    # layer_pattern cycles over the stack; entries: "attn" | "rwkv6" | "rglru"
    layer_pattern: Tuple[str, ...] = ("attn",)
    rwkv_head_dim: int = 64
    lru_width: int = 0  # rg-lru recurrent width (defaults to d_model)
    conv_width: int = 4  # rg-lru temporal conv

    # ---- embeddings / norms ----
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    has_lm_head: bool = True

    # ---- numerics ----
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    cache_dtype: str = ""  # "" => dtype; "float8_e4m3fn" halves KV memory
    #                        (needed for MHA-heavy archs at decode_32k:
    #                        qwen1.5-32b's 40-head cache is 5.5 TB in bf16)
    # optimizer moment dtype lives in TrainConfig; >=100B configs use bf16

    # ---- frontend stubs (audio/vlm): inputs are precomputed embeddings ----
    frontend_stub: bool = False

    def __post_init__(self):
        if self.attn_kind == "gqa" and self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0 and "rglru" in self.layer_pattern:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived ----
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can run the long_500k decode shape."""
        kinds = set(self.layer_kinds)
        if "attn" in kinds and self.local_window == 0:
            return False
        return True

    def param_count(self) -> int:
        """Analytic parameter count (drives 6·N·D roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if self.has_lm_head and not self.tie_embeddings:
            total += v * d
        for kind in self.layer_kinds:
            if kind == "attn":
                if self.attn_kind == "mla":
                    qh = self.qk_nope_head_dim + self.qk_rope_head_dim
                    q_in = self.q_lora_rank or d
                    if self.q_lora_rank:
                        total += d * self.q_lora_rank
                    total += q_in * self.num_heads * qh
                    total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    total += self.kv_lora_rank * self.num_heads * (
                        self.qk_nope_head_dim + self.v_head_dim
                    )
                    total += self.num_heads * self.v_head_dim * d
                else:
                    hd = self.head_dim
                    total += d * self.num_heads * hd
                    total += 2 * d * self.num_kv_heads * hd
                    total += self.num_heads * hd * d
            elif kind == "rwkv6":
                total += 6 * d * d  # r,k,v,g,w,out (lora terms are small)
                total += 2 * d * self.d_ff  # channel mix
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * d + 3 * w  # in/out proj + gates
            # MLP
            if kind != "rwkv6":  # rwkv6 blocks carry their own channel mix
                total += self._mlp_params(d)
        total += sum(self._norm_params(d) for _ in self.layer_kinds) * 2
        return total

    def _mlp_params(self, d: int) -> int:
        if self.is_moe:
            e_ff = self.moe_d_ff or self.d_ff
            routed = self.num_experts * 3 * d * e_ff
            shared = self.num_shared_experts * 3 * d * e_ff
            router = d * self.num_experts
            dense_layers = self.first_dense_layers
            moe_layers = self.num_layers - dense_layers
            # averaged per layer (called once per layer)
            per_moe = routed + shared + router
            per_dense = 3 * d * self.d_ff
            return (per_moe * moe_layers + per_dense * dense_layers) // self.num_layers
        mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        return mult * d * self.d_ff

    def _norm_params(self, d: int) -> int:
        return 2 * d if self.norm == "layernorm" else d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        e_ff = self.moe_d_ff or self.d_ff
        full = self.param_count()
        inactive = (self.num_experts - self.top_k) * 3 * d * e_ff
        moe_layers = self.num_layers - self.first_dense_layers
        return full - inactive * moe_layers
