"""Model wrapper: embeddings (token / stub-frontend / merged VLM), the layer
stack, final norm and LM head. Pure functions over a params pytree."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, init_norm
from repro.models.transformer import apply_stack, init_stack, init_stack_cache

__all__ = ["init_model", "forward", "init_cache", "default_positions"]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16,
            "float8_e4m3fn": jnp.float8_e4m3fn}[name]


def init_model(key, cfg: ModelConfig) -> dict:
    dtype = _dt(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "stack": init_stack(ks[1], cfg, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.has_lm_head and not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[2], (cfg.d_model, cfg.vocab_size), jnp.float32)
            * cfg.d_model ** -0.5
        ).astype(dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return init_stack_cache(cfg, batch, max_len,
                            _dt(cfg.cache_dtype or cfg.dtype))


def default_positions(cfg: ModelConfig, batch: int, seq: int,
                      offset: jax.Array | int = 0) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.pos_emb == "mrope":  # text tokens: t == h == w
        pos = jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


def embed_inputs(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Token embedding, with frontend stubs merged in.

    batch keys:
      tokens (B, S) int32            — always present (audio: frame ids)
      features (B, S, d)             — audio stub: precomputed frame
                                       embeddings replace the token path
      vision_embeds (B, S, d)        — vlm stub: precomputed patch
                                       embeddings, merged where vision_mask
      vision_mask (B, S) bool
    """
    dtype = _dt(cfg.dtype)
    if cfg.frontend_stub and "features" in batch:
        h = batch["features"].astype(dtype)
    else:
        h = params["embed"][batch["tokens"]].astype(dtype)
    if "vision_embeds" in batch:
        mask = batch["vision_mask"][..., None]
        h = jnp.where(mask, batch["vision_embeds"].astype(dtype), h)
    return h


def forward(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    remat: bool = False,
    attn_args: Optional[dict] = None,
    last_only: bool = False,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (logits (B,S,V), new_cache, aux_loss).

    ``last_only``: project only the final position through the LM head —
    the prefill path, where materializing (B, 32768, V) logits would burn
    terabytes for one needed row."""
    h = embed_inputs(params, cfg, batch)
    B, S = h.shape[:2]
    if positions is None:
        positions = batch.get("positions")
    if positions is None:
        offset = 0 if cache_index is None else cache_index
        positions = default_positions(cfg, B, S, offset)

    h, new_cache, aux = apply_stack(
        params["stack"], cfg, h, positions, cache, cache_index,
        attn_args=attn_args, remat=remat,
    )
    if last_only:
        h = h[:, -1:, :]
    h = apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    if not cfg.has_lm_head:
        return h, new_cache, aux
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = h @ params["lm_head"]
    return logits, new_cache, aux
