"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = σ(W_a x_t + b_a)            (recurrence gate)
    i_t = σ(W_x x_t + b_x)            (input gate)
    a_t = a^(c·r_t),  a = σ(Λ)        (data-dependent diagonal decay, c=8)
    h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t)

preceded by a short temporal conv1d (width 4) and wrapped by in/out
projections with a GeLU gate — the full Griffin recurrent block.

The diagonal linear recurrence is evaluated with an *associative scan*
(parallel prefix) over time: O(log S) depth, TPU-friendly — this (plus the
ring-buffer local-attention cache) is what makes recurrentgemma run the
long_500k shape. Decode carries (h, conv window) per layer: O(1)/token.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense, init_dense

__all__ = ["init_rglru", "rglru_block", "init_rglru_state"]

C_EXP = 8.0


def init_rglru(key, cfg: ModelConfig, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    return {
        "w_in": init_dense(ks[0], d, w, dtype),  # recurrent branch input
        "w_gate_in": init_dense(ks[1], d, w, dtype),  # gelu gate branch
        "w_out": init_dense(ks[2], w, d, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": init_dense(ks[4], w, w, dtype),
        "wx": init_dense(ks[5], w, w, dtype),
        # Λ init so that a = σ(Λ) ∈ (0.9, 0.999) as in the paper
        "lam": jnp.asarray(
            jnp.log(jnp.linspace(0.9, 0.999, w) / (1 - jnp.linspace(0.9, 0.999, w))),
            dtype,
        ),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def _causal_conv(x, w, b, carry):
    """Depthwise causal conv1d. x: (B,S,w); carry: (B,cw-1,w)."""
    cw = w.shape[0]
    xp = jnp.concatenate([carry.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    new_carry = xp[:, -(cw - 1) :, :] if cw > 1 else carry
    return out + b, new_carry


def _lru_scan(a, u, h0):
    """h_t = a_t ⊙ h_{t-1} + u_t via associative scan. a,u: (B,S,w) fp32."""
    # incorporate initial state as a virtual first element
    u0 = u.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, a2 * u1 + u2

    a_s, h = jax.lax.associative_scan(combine, (a, u0), axis=1)
    return h


def rglru_block(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d) — already normed by the caller
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    st = state or init_rglru_state(cfg, B, x.dtype)

    from repro.distributed.actsharding import shard_act

    gate = jax.nn.gelu(dense(params["w_gate_in"], x))
    u = shard_act(dense(params["w_in"], x), "dp", None, "model")
    u, conv_carry = _causal_conv(u, params["conv_w"], params["conv_b"], st["conv"])

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(params["wa"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["wx"], u).astype(jnp.float32))
    log_a = C_EXP * r * jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    a = shard_act(jnp.exp(log_a), "dp", None, "model")
    drive = jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12, 1.0)) * (i * uf)
    drive = shard_act(drive, "dp", None, "model")
    h = _lru_scan(a, drive, st["h"])  # (B,S,w) fp32
    h = shard_act(h, "dp", None, "model")

    y = dense(params["w_out"], (h.astype(x.dtype) * gate))
    new_state = None
    if state is not None:
        new_state = {"h": h[:, -1, :], "conv": conv_carry}
    return y, new_state
