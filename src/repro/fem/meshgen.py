"""Structured simplicial meshes: unit square into triangles, unit cube into
tetrahedra (paper §4: "square or cube domain uniformly discretized into
triangles or tetrahedra").

Topology is host-side numpy (it is the symbolic part of the pipeline and is
fixed across the multi-step simulation); values flow through JAX downstream.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Mesh", "structured_mesh"]


@dataclasses.dataclass(frozen=True)
class Mesh:
    """A simplicial mesh: P1 nodes + element connectivity."""

    dim: int
    coords: np.ndarray  # (n_nodes, dim) float64
    elems: np.ndarray  # (n_elems, dim+1) int64

    @property
    def n_nodes(self) -> int:
        return self.coords.shape[0]

    @property
    def n_elems(self) -> int:
        return self.elems.shape[0]


def _grid_coords(shape: tuple[int, ...], origin, spacing) -> np.ndarray:
    axes = [origin[d] + spacing[d] * np.arange(shape[d] + 1) for d in range(len(shape))]
    grids = np.meshgrid(*axes, indexing="ij")
    return np.stack([g.ravel(order="F") for g in grids], axis=1)


def _node_id(shape: tuple[int, ...], *idx) -> np.ndarray:
    """Fortran-order node id on an (n0+1, n1+1, ...) node grid."""
    strides = [1]
    for d in range(len(shape) - 1):
        strides.append(strides[-1] * (shape[d] + 1))
    return sum(np.asarray(idx[d]) * strides[d] for d in range(len(shape)))


def structured_mesh(
    shape: tuple[int, ...],
    origin: tuple[float, ...] | None = None,
    lengths: tuple[float, ...] | None = None,
) -> Mesh:
    """Uniform simplicial mesh of a box.

    2D: each of the ``nx*ny`` squares is split into 2 triangles.
    3D: each of the ``nx*ny*nz`` cubes is split into 6 tetrahedra (Kuhn).
    """
    dim = len(shape)
    if dim not in (2, 3):
        raise ValueError("only 2D/3D structured meshes are supported")
    origin = origin or (0.0,) * dim
    lengths = lengths or (1.0,) * dim
    spacing = tuple(lengths[d] / shape[d] for d in range(dim))
    coords = _grid_coords(shape, origin, spacing)

    if dim == 2:
        nx, ny = shape
        ix, iy = np.meshgrid(np.arange(nx), np.arange(ny), indexing="ij")
        ix, iy = ix.ravel(), iy.ravel()
        v00 = _node_id(shape, ix, iy)
        v10 = _node_id(shape, ix + 1, iy)
        v01 = _node_id(shape, ix, iy + 1)
        v11 = _node_id(shape, ix + 1, iy + 1)
        t1 = np.stack([v00, v10, v11], axis=1)
        t2 = np.stack([v00, v11, v01], axis=1)
        elems = np.concatenate([t1, t2], axis=0)
    else:
        nx, ny, nz = shape
        ix, iy, iz = np.meshgrid(
            np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij"
        )
        ix, iy, iz = ix.ravel(), iy.ravel(), iz.ravel()

        def corner(dx, dy, dz):
            return _node_id(shape, ix + dx, iy + dy, iz + dz)

        # Kuhn / staircase decomposition: for each of the 6 axis orders,
        # tet = [c000, c000+e_a, c000+e_a+e_b, c111].
        import itertools

        e = {0: (1, 0, 0), 1: (0, 1, 0), 2: (0, 0, 1)}
        tets = []
        for a, b, c in itertools.permutations((0, 1, 2)):
            p0 = corner(0, 0, 0)
            s1 = e[a]
            p1 = corner(*s1)
            s2 = tuple(s1[d] + e[b][d] for d in range(3))
            p2 = corner(*s2)
            p3 = corner(1, 1, 1)
            tets.append(np.stack([p0, p1, p2, p3], axis=1))
        elems = np.concatenate(tets, axis=0)

    return Mesh(dim=dim, coords=coords, elems=elems.astype(np.int64))
