"""FEM substrate: structured meshes (paper §4's benchmark geometry), P1
stiffness assembly for scalar heat and vector linear elasticity, and the
total-FETI domain decomposition (subdomains, gluing matrices B, Dirichlet
constraints, kernel bases + fixing-DOF regularization)."""
from repro.fem.assembly import (
    assemble_dense,
    assemble_scipy_csr,
    elasticity_load_vector,
    elasticity_matrix,
    element_dofs,
    load_vector,
    p1_elasticity_stiffness,
    p1_element_stiffness,
)
from repro.fem.decomposition import (
    FetiProblem,
    SubdomainData,
    decompose_elasticity_problem,
    decompose_heat_problem,
    decompose_problem,
)
from repro.fem.meshgen import Mesh, structured_mesh
from repro.fem.regularization import (
    fixing_dofs_regularization,
    fixing_node_regularization,
    kernel_basis,
    rigid_body_modes,
)

__all__ = [
    "FetiProblem",
    "Mesh",
    "SubdomainData",
    "assemble_dense",
    "assemble_scipy_csr",
    "decompose_elasticity_problem",
    "decompose_heat_problem",
    "decompose_problem",
    "elasticity_load_vector",
    "elasticity_matrix",
    "element_dofs",
    "fixing_dofs_regularization",
    "fixing_node_regularization",
    "kernel_basis",
    "load_vector",
    "p1_elasticity_stiffness",
    "p1_element_stiffness",
    "rigid_body_modes",
    "structured_mesh",
]
