"""FEM substrate: structured heat-transfer meshes (paper §4's benchmark
problem), P1 stiffness assembly, and the total-FETI domain decomposition
(subdomains, gluing matrices B, Dirichlet constraints)."""
from repro.fem.assembly import (
    assemble_dense,
    assemble_scipy_csr,
    load_vector,
    p1_element_stiffness,
)
from repro.fem.decomposition import (
    FetiProblem,
    SubdomainData,
    decompose_heat_problem,
)
from repro.fem.meshgen import Mesh, structured_mesh
from repro.fem.regularization import fixing_node_regularization, kernel_basis

__all__ = [
    "FetiProblem",
    "Mesh",
    "SubdomainData",
    "assemble_dense",
    "assemble_scipy_csr",
    "decompose_heat_problem",
    "fixing_node_regularization",
    "kernel_basis",
    "load_vector",
    "p1_element_stiffness",
    "structured_mesh",
]
