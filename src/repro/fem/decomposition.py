"""Total-FETI domain decomposition of the structured heat-transfer problem.

Decomposes a structured box into a grid of equally-sized box subdomains
(paper Fig. 2), duplicates interface nodes, and builds:

  * per-subdomain stiffness ``K_i`` (SPSD, kernel = constants) and load ``f_i``,
  * the signed boolean gluing matrix ``B`` as per-subdomain dense blocks
    ``B̃ᵢᵀ`` (n_i × m_i) plus global multiplier ids (non-redundant chain
    gluing between node copies),
  * Dirichlet conditions on the x=0 face enforced as constraints (total
    FETI: every subdomain stays floating, kernels are uniform),
  * a fixing node per subdomain for the analytic regularization [11].

All subdomains share the same local topology (same structured box), which is
what lets the solver batch them through one compiled program — the TPU
analogue of the paper's per-stream subdomain loop.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List

import numpy as np

from repro.fem.assembly import (
    assemble_dense,
    assemble_scipy_csr,
    load_vector,
    p1_element_stiffness,
)
from repro.fem.meshgen import Mesh, structured_mesh

__all__ = ["SubdomainData", "FetiProblem", "decompose_heat_problem"]


@dataclasses.dataclass
class SubdomainData:
    """One subdomain's local system and gluing.

    Every local multiplier column of B̃ᵀ has exactly ONE ±1 entry (chain
    gluing / Dirichlet pinning), recorded compactly in (b_rows, b_vals);
    the dense Bt is derived from them (and is a placeholder in
    pattern-only mode).
    """

    index: int
    K: np.ndarray  # (n_i, n_i) dense SPSD stiffness (or 1x1 placeholder)
    f: np.ndarray  # (n_i,) load (or placeholder)
    Bt: np.ndarray  # (n_i, m_max) dense ±1, zero-padded columns
    lambda_ids: np.ndarray  # (m_max,) global multiplier ids; pad = n_lambda
    m: int  # actual number of local multipliers
    node_gids: np.ndarray  # (n_i,) global node ids
    fixing_node: int  # local node id for regularization
    b_rows: np.ndarray = None  # (m_max,) local row of each column's ±1
    b_vals: np.ndarray = None  # (m_max,) the ±1 values

    @property
    def n(self) -> int:
        return len(self.node_gids)


@dataclasses.dataclass
class FetiProblem:
    """The decomposed problem + everything needed for validation."""

    dim: int
    sub_grid: tuple
    elems_per_sub: tuple
    n_lambda: int
    subdomains: List[SubdomainData]
    c: np.ndarray  # (n_lambda,) constraint rhs (Dirichlet values; zeros here)
    global_mesh: Mesh
    dirichlet_gids: np.ndarray

    @property
    def n_subdomains(self) -> int:
        return len(self.subdomains)

    @property
    def m_max(self) -> int:
        return self.subdomains[0].Bt.shape[1]

    # ---- reference oracle: undecomposed global solve (tests only) ----
    def reference_solution(self) -> np.ndarray:
        """Direct sparse solve of the global system with Dirichlet BC."""
        import scipy.sparse.linalg as spla

        mesh = self.global_mesh
        Ke = np.asarray(p1_element_stiffness(mesh.coords, mesh.elems))
        K = assemble_scipy_csr(mesh.n_nodes, mesh.elems, Ke)
        f = np.asarray(load_vector(mesh.coords, mesh.elems, mesh.n_nodes))
        free = np.setdiff1d(np.arange(mesh.n_nodes), self.dirichlet_gids)
        u = np.zeros(mesh.n_nodes)
        u[free] = spla.spsolve(K[free][:, free].tocsc(), f[free])
        return u


def _box_ranges(dim, sub_grid, elems_per_sub):
    for s in itertools.product(*[range(sub_grid[d]) for d in range(dim)]):
        yield s


def decompose_heat_problem(
    dim: int,
    sub_grid: tuple,
    elems_per_sub: tuple,
    kappa: float = 1.0,
    source: float = 1.0,
    dtype=np.float64,
    assemble_values: bool = True,
) -> FetiProblem:
    """Build the total-FETI decomposition of the structured heat problem.

    Args:
      dim: 2 or 3.
      sub_grid: number of subdomains per axis, e.g. (4, 4) or (2, 2, 2).
      elems_per_sub: elements per axis per subdomain, e.g. (8, 8).
      assemble_values: if False, build topology/patterns only (K and f are
        1x1 placeholders) — the dry-run path, which needs the static
        stepped/symbolic metadata of production-sized subdomains without
        allocating their dense matrices.
    """
    if dim != len(sub_grid) or dim != len(elems_per_sub):
        raise ValueError("dim / sub_grid / elems_per_sub mismatch")
    gshape = tuple(sub_grid[d] * elems_per_sub[d] for d in range(dim))
    gmesh = structured_mesh(gshape)
    gnode_shape = tuple(g + 1 for g in gshape)
    gstrides = [1]
    for d in range(dim - 1):
        gstrides.append(gstrides[-1] * gnode_shape[d])

    def gid_of(idx):  # idx: (dim,) ints
        return sum(int(idx[d]) * gstrides[d] for d in range(dim))

    # local template mesh, shared by all subdomains (same topology)
    spacing = tuple(1.0 / gshape[d] for d in range(dim))
    sub_lengths = tuple(elems_per_sub[d] * spacing[d] for d in range(dim))

    sub_list = list(_box_ranges(dim, sub_grid, elems_per_sub))
    n_subs = len(sub_list)

    # --- per-subdomain meshes, K_i, f_i ---
    Ks, fs, gids_per_sub = [], [], []
    lshape = tuple(elems_per_sub[d] + 1 for d in range(dim))  # nodes per axis
    lstrides = [1]
    for d in range(dim - 1):
        lstrides.append(lstrides[-1] * lshape[d])
    # local node multi-indices in Fortran order
    lranges = [np.arange(lshape[d]) for d in range(dim)]
    lgrid = np.meshgrid(*lranges, indexing="ij")
    lidx = np.stack([g.ravel(order="F") for g in lgrid], axis=1)  # (n_i, dim)

    n_local = int(np.prod(lshape))
    for si, s in enumerate(sub_list):
        if assemble_values:
            origin = tuple(s[d] * sub_lengths[d] for d in range(dim))
            lmesh = structured_mesh(elems_per_sub, origin=origin,
                                    lengths=sub_lengths)
            Ke = np.asarray(
                p1_element_stiffness(lmesh.coords, lmesh.elems, kappa=kappa)
            )
            K = np.asarray(
                assemble_dense(lmesh.n_nodes, lmesh.elems, Ke)
            ).astype(dtype)
            f = np.asarray(
                load_vector(lmesh.coords, lmesh.elems, lmesh.n_nodes,
                            source=source)
            ).astype(dtype)
        else:  # pattern-only: placeholders carry just the size via .n
            K = np.zeros((1, 1), dtype)
            f = np.zeros((1,), dtype)
        gnode = lidx + np.array([s[d] * elems_per_sub[d] for d in range(dim)])
        gids = (gnode * np.array(gstrides)).sum(axis=1)
        Ks.append(K)
        fs.append(f)
        gids_per_sub.append(gids.astype(np.int64))

    # --- ownership: global node -> [(sub, local_id)] ---
    owners: dict[int, list[tuple[int, int]]] = {}
    for si, gids in enumerate(gids_per_sub):
        for lid, g in enumerate(gids):
            owners.setdefault(int(g), []).append((si, lid))

    # --- multipliers ---
    # 1) gluing: chain over the (sub-sorted) copies of each shared node
    # 2) Dirichlet x=0 face: one constraint per copy (total FETI)
    triplets: list[list[tuple[int, int, float]]] = [[] for _ in range(n_subs)]
    c_rows: list[float] = []
    n_lambda = 0
    dirichlet_gids = []
    for g in sorted(owners):
        copies = owners[g]
        if g % gnode_shape[0] == 0:
            # Dirichlet at x=0 (first axis index == 0): pin every copy.
            # Chain gluing is skipped here — pinning already implies
            # equality, keeping the constraint set non-redundant.
            dirichlet_gids.append(g)
            for (sa, la) in copies:
                triplets[sa].append((la, n_lambda, 1.0))
                c_rows.append(0.0)
                n_lambda += 1
        else:
            for (sa, la), (sb, lb) in zip(copies, copies[1:]):
                triplets[sa].append((la, n_lambda, 1.0))
                triplets[sb].append((lb, n_lambda, -1.0))
                c_rows.append(0.0)
                n_lambda += 1

    m_per_sub = [len(t) for t in triplets]
    m_max = max(m_per_sub)

    # --- fixing node: subdomain center (paper's analytic regularization) ---
    center = tuple(lshape[d] // 2 for d in range(dim))
    fixing_local = sum(center[d] * lstrides[d] for d in range(dim))

    subdomains = []
    for si in range(n_subs):
        n_i = n_local
        lam = np.full((m_max,), n_lambda, dtype=np.int64)  # pad -> dummy slot
        b_rows = np.zeros((m_max,), dtype=np.int64)
        b_vals = np.zeros((m_max,), dtype=dtype)
        for col, (lid, gl, val) in enumerate(triplets[si]):
            lam[col] = gl
            b_rows[col] = lid
            b_vals[col] = val
        if assemble_values:
            Bt = np.zeros((n_i, m_max), dtype=dtype)
            Bt[b_rows[: m_per_sub[si]], np.arange(m_per_sub[si])] = b_vals[
                : m_per_sub[si]
            ]
        else:
            Bt = np.zeros((1, m_max), dtype=dtype)  # placeholder
        subdomains.append(
            SubdomainData(
                index=si,
                K=Ks[si],
                f=fs[si],
                Bt=Bt,
                lambda_ids=lam,
                m=m_per_sub[si],
                node_gids=gids_per_sub[si],
                fixing_node=int(fixing_local),
                b_rows=b_rows,
                b_vals=b_vals,
            )
        )

    return FetiProblem(
        dim=dim,
        sub_grid=tuple(sub_grid),
        elems_per_sub=tuple(elems_per_sub),
        n_lambda=n_lambda,
        subdomains=subdomains,
        c=np.asarray(c_rows, dtype=dtype),
        global_mesh=gmesh,
        dirichlet_gids=np.asarray(sorted(set(dirichlet_gids)), dtype=np.int64),
    )
