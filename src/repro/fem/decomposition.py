"""Total-FETI domain decomposition of structured heat-transfer and
linear-elasticity problems.

Decomposes a structured box into a grid of equally-sized box subdomains
(paper Fig. 2), duplicates interface nodes, and builds:

  * per-subdomain stiffness ``K_i`` (SPSD) and load ``f_i`` — scalar P1
    heat (kernel = constants, k = 1) or node-blocked vector P1 linear
    elasticity (kernel = rigid-body modes, k = 3 in 2D / 6 in 3D),
  * the signed boolean gluing matrix ``B`` as per-subdomain dense blocks
    ``B̃ᵢᵀ`` (n_i × m_i) plus global multiplier ids (non-redundant chain
    gluing between DOF copies; vector problems glue every component),
  * Dirichlet conditions on the x=0 face enforced as constraints (total
    FETI: every subdomain stays floating, kernels are uniform),
  * the orthonormal kernel basis ``R_i`` (n_i × k) and k fixing DOFs per
    subdomain for the analytic regularization [11] — see
    :mod:`repro.fem.regularization`.

All subdomains share the same local topology (same structured box), which
is what lets the solver batch them through one compiled program — the TPU
analogue of the paper's per-stream subdomain loop. They also share the
kernel basis: the local template's rigid-body modes span every translated
copy's kernel (a rotation about a shifted origin is that rotation plus a
translation).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List

import numpy as np

from repro.fem.assembly import (
    assemble_dense,
    assemble_scipy_csr,
    elasticity_load_vector,
    element_dofs,
    load_vector,
    p1_elasticity_stiffness,
    p1_element_stiffness,
)
from repro.fem.meshgen import Mesh, structured_mesh
from repro.fem.regularization import kernel_basis

__all__ = [
    "SubdomainData",
    "FetiProblem",
    "decompose_problem",
    "decompose_heat_problem",
    "decompose_elasticity_problem",
]

DEFAULT_BODY_FORCE = {2: (0.0, -1.0), 3: (0.0, 0.0, -1.0)}


@dataclasses.dataclass
class SubdomainData:
    """One subdomain's local system and gluing.

    Every local multiplier column of B̃ᵀ has exactly ONE ±1 entry (chain
    gluing / Dirichlet pinning), recorded compactly in (b_rows, b_vals);
    the dense Bt is derived from them (and is a placeholder in
    pattern-only mode). Rows of K / f / Bt / R are DOFs in node-blocked
    order (DOF = node*ndpn + component; ndpn = 1 for heat).
    """

    index: int
    K: np.ndarray  # (n_i, n_i) dense SPSD stiffness (or 1x1 placeholder)
    f: np.ndarray  # (n_i,) load (or placeholder)
    Bt: np.ndarray  # (n_i, m_max) dense ±1, zero-padded columns
    lambda_ids: np.ndarray  # (m_max,) global multiplier ids; pad = n_lambda
    m: int  # actual number of local multipliers
    node_gids: np.ndarray  # (n_nodes_i,) global node ids
    dof_gids: np.ndarray  # (n_i,) global DOF ids (= node_gids for heat)
    fixing_node: int  # local node id anchoring the regularization
    R: np.ndarray = None  # (n_i, k) orthonormal kernel basis
    fixing_dofs: np.ndarray = None  # (k,) local DOFs; R[fixing_dofs] invertible
    b_rows: np.ndarray = None  # (m_max,) local row of each column's ±1
    b_vals: np.ndarray = None  # (m_max,) the ±1 values

    @property
    def n(self) -> int:
        return len(self.dof_gids)


@dataclasses.dataclass
class FetiProblem:
    """The decomposed problem + everything needed for validation."""

    dim: int
    sub_grid: tuple
    elems_per_sub: tuple
    n_lambda: int
    subdomains: List[SubdomainData]
    c: np.ndarray  # (n_lambda,) constraint rhs (Dirichlet values; zeros here)
    global_mesh: Mesh
    dirichlet_gids: np.ndarray  # global NODE ids on the x=0 face
    problem: str = "heat"
    ndof_per_node: int = 1
    kernel_dim: int = 1
    params: dict = dataclasses.field(default_factory=dict)

    @property
    def n_subdomains(self) -> int:
        return len(self.subdomains)

    @property
    def m_max(self) -> int:
        return self.subdomains[0].Bt.shape[1]

    @property
    def n_global_dofs(self) -> int:
        return self.global_mesh.n_nodes * self.ndof_per_node

    @property
    def dirichlet_dofs(self) -> np.ndarray:
        """Global DOF ids pinned by the Dirichlet face (all components)."""
        ndpn = self.ndof_per_node
        return (self.dirichlet_gids[:, None] * ndpn
                + np.arange(ndpn)).reshape(-1)

    # ---- multi-RHS load cases (solver inputs) ----
    def load_stack(self) -> np.ndarray:
        """The problem's own per-subdomain loads as one (S, n) stack —
        the single-load-case input of :meth:`FetiSolver.solve_many`."""
        return np.stack([sd.f for sd in self.subdomains])

    def load_cases(self, n_rhs: int, kind: str = "sweep",
                   seed: int = 0) -> np.ndarray:
        """(n_rhs, S, n) stacked load cases for the multi-RHS solve path.

        ``kind="sweep"`` scales the assembled body load by 1, 2, …
        (a load sweep / pseudo-time-stepping stand-in whose solutions are
        the scaled base solution); ``kind="random"`` draws i.i.d. normal
        per-DOF loads normalized to the base load magnitude (a stand-in
        for many independent user requests, each with its own convergence
        history); ``kind="mixed"`` keeps the base load as column 0, a
        zero load (converged at iteration 0) as column 1, and random
        columns after — the shape the per-column-stopping tests use.
        Every case is a legal FETI load: the matching global problem has
        RHS :meth:`global_load` (the subdomain-assembled sum).
        """
        base = self.load_stack()
        if kind == "sweep":
            scales = 1.0 + np.arange(n_rhs, dtype=float)
            return scales[:, None, None] * base[None]
        rng = np.random.default_rng(seed)
        norm = np.abs(base).max()
        rand = rng.standard_normal((n_rhs,) + base.shape) * norm
        if kind == "random":
            return rand
        if kind == "mixed":
            cases = rand
            cases[0] = base
            if n_rhs > 1:
                cases[1] = 0.0
            return cases
        raise ValueError(f"unknown load-case kind {kind!r}")

    def global_load(self, loads: np.ndarray) -> np.ndarray:
        """Assemble one (S, n) per-subdomain load stack into the
        (n_global_dofs,) global RHS: interface DOFs sum their subdomain
        copies, exactly how the decomposition splits an integrated body
        load (subdomain elements partition the global elements)."""
        f = np.zeros(self.n_global_dofs)
        for i, sd in enumerate(self.subdomains):
            np.add.at(f, sd.dof_gids, loads[i])
        return f

    # ---- reference oracle: undecomposed global solve (tests only) ----
    def _global_system(self):
        """Assembled global (K csr, f, free-DOF ids) with Dirichlet BC."""
        mesh = self.global_mesh
        if self.problem == "heat":
            Ke = np.asarray(p1_element_stiffness(
                mesh.coords, mesh.elems, kappa=self.params.get("kappa", 1.0)))
            edofs = mesh.elems
            f = np.asarray(load_vector(
                mesh.coords, mesh.elems, mesh.n_nodes,
                source=self.params.get("source", 1.0)))
        else:
            Ke = np.asarray(p1_elasticity_stiffness(
                mesh.coords, mesh.elems,
                lam=self.params.get("lam", 1.0),
                mu=self.params.get("mu", 1.0)))
            edofs = element_dofs(mesh.elems, self.dim)
            f = np.asarray(elasticity_load_vector(
                mesh.coords, mesh.elems, mesh.n_nodes,
                self.params.get("body_force", DEFAULT_BODY_FORCE[self.dim])))
        nd = self.n_global_dofs
        K = assemble_scipy_csr(nd, edofs, Ke)
        free = np.setdiff1d(np.arange(nd), self.dirichlet_dofs)
        return K, f, free

    def reference_solution(self, loads: np.ndarray = None) -> np.ndarray:
        """Direct sparse solve of the global system with Dirichlet BC.

        Returns the (n_global_dofs,) solution in node-blocked DOF order.
        ``loads`` (optional, a (S, n) per-subdomain stack) overrides the
        problem's own body load with :meth:`global_load` of the stack —
        the per-case oracle for :meth:`FetiSolver.solve_many`.
        """
        import scipy.sparse.linalg as spla

        K, f, free = self._global_system()
        if loads is not None:
            f = self.global_load(loads)
        u = np.zeros(self.n_global_dofs)
        u[free] = spla.spsolve(K[free][:, free].tocsc(), f[free])
        return u

    def reference_solutions(self, cases: np.ndarray) -> np.ndarray:
        """Per-column oracle for a (n_rhs, S, n) load-case stack: one
        sparse factorization, all columns solved against it. Returns
        (n_rhs, n_global_dofs) in node-blocked DOF order."""
        import scipy.sparse.linalg as spla

        K, _, free = self._global_system()
        F = np.stack([self.global_load(c)[free] for c in cases], axis=1)
        solve = spla.factorized(K[free][:, free].tocsc())
        U = np.zeros((len(cases), self.n_global_dofs))
        U[:, free] = np.stack([solve(F[:, j]) for j in range(F.shape[1])])
        return U


def _box_ranges(dim, sub_grid, elems_per_sub):
    for s in itertools.product(*[range(sub_grid[d]) for d in range(dim)]):
        yield s


def _fixing_dofs(problem: str, dim: int, lshape: tuple, lstrides: list,
                 fixing_node: int) -> np.ndarray:
    """k local DOFs with R[fixing_dofs] invertible (regularization §docs).

    Heat: the fixing node itself. Elasticity: the 3-2-1 locating fixture
    over spread-out corner nodes of the subdomain box.
    """
    if problem == "heat":
        return np.asarray([fixing_node], dtype=np.int64)
    nx = lshape[0] - 1  # node index of the far x corner
    node_a = 0  # local node (0, 0[, 0])
    node_b = nx * lstrides[0]  # (nx, 0[, 0]): differs from A along x
    if dim == 2:
        # A.ux, A.uy pin translations; B.uy pins the rotation
        return np.asarray([2 * node_a, 2 * node_a + 1, 2 * node_b + 1],
                          dtype=np.int64)
    node_c = (lshape[1] - 1) * lstrides[1]  # (0, ny, 0): off the AB axis
    return np.asarray(
        [3 * node_a, 3 * node_a + 1, 3 * node_a + 2,
         3 * node_b + 1, 3 * node_b + 2,
         3 * node_c + 2],
        dtype=np.int64)


def decompose_problem(
    problem: str,
    dim: int,
    sub_grid: tuple,
    elems_per_sub: tuple,
    kappa: float = 1.0,
    source: float = 1.0,
    lam: float = 1.0,
    mu: float = 1.0,
    body_force=None,
    dtype=np.float64,
    assemble_values: bool = True,
) -> FetiProblem:
    """Build the total-FETI decomposition of a structured problem.

    Args:
      problem: "heat" (scalar P1, k=1) or "elasticity" (vector P1,
        node-blocked DOFs, k=3/6).
      dim: 2 or 3.
      sub_grid: number of subdomains per axis, e.g. (4, 4) or (2, 2, 2).
      elems_per_sub: elements per axis per subdomain, e.g. (8, 8).
      kappa/source: heat conductivity and source term (heat only).
      lam/mu/body_force: Lamé parameters and constant body force
        (elasticity only; body_force defaults to unit downward gravity).
      assemble_values: if False, build topology/patterns only (K and f are
        1x1 placeholders) — the dry-run path, which needs the static
        stepped/symbolic metadata of production-sized subdomains without
        allocating their dense matrices.
    """
    if problem not in ("heat", "elasticity"):
        raise ValueError(f"unknown problem {problem!r}")
    if dim != len(sub_grid) or dim != len(elems_per_sub):
        raise ValueError("dim / sub_grid / elems_per_sub mismatch")
    ndpn = 1 if problem == "heat" else dim
    if body_force is None:
        body_force = DEFAULT_BODY_FORCE[dim]
    gshape = tuple(sub_grid[d] * elems_per_sub[d] for d in range(dim))
    gmesh = structured_mesh(gshape)
    gnode_shape = tuple(g + 1 for g in gshape)
    gstrides = [1]
    for d in range(dim - 1):
        gstrides.append(gstrides[-1] * gnode_shape[d])

    # local template mesh, shared by all subdomains (same topology)
    spacing = tuple(1.0 / gshape[d] for d in range(dim))
    sub_lengths = tuple(elems_per_sub[d] * spacing[d] for d in range(dim))

    sub_list = list(_box_ranges(dim, sub_grid, elems_per_sub))
    n_subs = len(sub_list)

    # --- per-subdomain meshes, K_i, f_i ---
    Ks, fs, gids_per_sub = [], [], []
    lshape = tuple(elems_per_sub[d] + 1 for d in range(dim))  # nodes per axis
    lstrides = [1]
    for d in range(dim - 1):
        lstrides.append(lstrides[-1] * lshape[d])
    # local node multi-indices in Fortran order
    lranges = [np.arange(lshape[d]) for d in range(dim)]
    lgrid = np.meshgrid(*lranges, indexing="ij")
    lidx = np.stack([g.ravel(order="F") for g in lgrid], axis=1)  # (n_i, dim)

    n_nodes_local = int(np.prod(lshape))
    n_local = n_nodes_local * ndpn
    for si, s in enumerate(sub_list):
        if assemble_values:
            origin = tuple(s[d] * sub_lengths[d] for d in range(dim))
            lmesh = structured_mesh(elems_per_sub, origin=origin,
                                    lengths=sub_lengths)
            if problem == "heat":
                Ke = np.asarray(p1_element_stiffness(
                    lmesh.coords, lmesh.elems, kappa=kappa))
                edofs = lmesh.elems
                f = np.asarray(load_vector(
                    lmesh.coords, lmesh.elems, lmesh.n_nodes, source=source))
            else:
                Ke = np.asarray(p1_elasticity_stiffness(
                    lmesh.coords, lmesh.elems, lam=lam, mu=mu))
                edofs = element_dofs(lmesh.elems, dim)
                f = np.asarray(elasticity_load_vector(
                    lmesh.coords, lmesh.elems, lmesh.n_nodes, body_force))
            K = np.asarray(assemble_dense(n_local, edofs, Ke)).astype(dtype)
            f = f.astype(dtype)
        else:  # pattern-only: placeholders carry just the size via dof_gids
            K = np.zeros((1, 1), dtype)
            f = np.zeros((1,), dtype)
        gnode = lidx + np.array([s[d] * elems_per_sub[d] for d in range(dim)])
        gids = (gnode * np.array(gstrides)).sum(axis=1)
        Ks.append(K)
        fs.append(f)
        gids_per_sub.append(gids.astype(np.int64))

    # shared kernel basis: the local template's constants / rigid modes
    lmesh0 = structured_mesh(elems_per_sub, lengths=sub_lengths)
    if problem == "heat":
        R_shared = kernel_basis(n_local, "heat", dtype=dtype)
    else:
        R_shared = kernel_basis(problem="elasticity", coords=lmesh0.coords,
                                dtype=dtype)
    kdim = R_shared.shape[1]

    # --- ownership: global node -> [(sub, local_id)] ---
    owners: dict[int, list[tuple[int, int]]] = {}
    for si, gids in enumerate(gids_per_sub):
        for lid, g in enumerate(gids):
            owners.setdefault(int(g), []).append((si, lid))

    # --- multipliers (one per node copy pair / pinned copy, per component) ---
    # 1) gluing: chain over the (sub-sorted) copies of each shared node
    # 2) Dirichlet x=0 face: one constraint per copy (total FETI)
    triplets: list[list[tuple[int, int, float]]] = [[] for _ in range(n_subs)]
    c_rows: list[float] = []
    n_lambda = 0
    dirichlet_gids = []
    for g in sorted(owners):
        copies = owners[g]
        if g % gnode_shape[0] == 0:
            # Dirichlet at x=0 (first axis index == 0): pin every copy.
            # Chain gluing is skipped here — pinning already implies
            # equality, keeping the constraint set non-redundant.
            dirichlet_gids.append(g)
            for (sa, la) in copies:
                for comp in range(ndpn):
                    triplets[sa].append((la * ndpn + comp, n_lambda, 1.0))
                    c_rows.append(0.0)
                    n_lambda += 1
        else:
            for (sa, la), (sb, lb) in zip(copies, copies[1:]):
                for comp in range(ndpn):
                    triplets[sa].append((la * ndpn + comp, n_lambda, 1.0))
                    triplets[sb].append((lb * ndpn + comp, n_lambda, -1.0))
                    c_rows.append(0.0)
                    n_lambda += 1

    m_per_sub = [len(t) for t in triplets]
    m_max = max(m_per_sub)

    # --- fixing node: subdomain center (paper's analytic regularization);
    # the k fixing DOFs generalize it for vector kernels ---
    center = tuple(lshape[d] // 2 for d in range(dim))
    fixing_local = sum(center[d] * lstrides[d] for d in range(dim))
    fix_dofs = _fixing_dofs(problem, dim, lshape, lstrides, int(fixing_local))

    subdomains = []
    for si in range(n_subs):
        n_i = n_local
        lam_ids = np.full((m_max,), n_lambda, dtype=np.int64)  # pad -> dummy
        b_rows = np.zeros((m_max,), dtype=np.int64)
        b_vals = np.zeros((m_max,), dtype=dtype)
        for col, (lid, gl, val) in enumerate(triplets[si]):
            lam_ids[col] = gl
            b_rows[col] = lid
            b_vals[col] = val
        if assemble_values:
            Bt = np.zeros((n_i, m_max), dtype=dtype)
            Bt[b_rows[: m_per_sub[si]], np.arange(m_per_sub[si])] = b_vals[
                : m_per_sub[si]
            ]
        else:
            Bt = np.zeros((1, m_max), dtype=dtype)  # placeholder
        gids = gids_per_sub[si]
        dof_gids = (gids[:, None] * ndpn
                    + np.arange(ndpn)).reshape(-1) if ndpn > 1 else gids
        subdomains.append(
            SubdomainData(
                index=si,
                K=Ks[si],
                f=fs[si],
                Bt=Bt,
                lambda_ids=lam_ids,
                m=m_per_sub[si],
                node_gids=gids,
                dof_gids=dof_gids,
                fixing_node=int(fixing_local),
                R=R_shared,
                fixing_dofs=fix_dofs,
                b_rows=b_rows,
                b_vals=b_vals,
            )
        )

    params = (dict(kappa=kappa, source=source) if problem == "heat"
              else dict(lam=lam, mu=mu, body_force=tuple(body_force)))
    return FetiProblem(
        dim=dim,
        sub_grid=tuple(sub_grid),
        elems_per_sub=tuple(elems_per_sub),
        n_lambda=n_lambda,
        subdomains=subdomains,
        c=np.asarray(c_rows, dtype=dtype),
        global_mesh=gmesh,
        dirichlet_gids=np.asarray(sorted(set(dirichlet_gids)), dtype=np.int64),
        problem=problem,
        ndof_per_node=ndpn,
        kernel_dim=kdim,
        params=params,
    )


def decompose_heat_problem(
    dim: int,
    sub_grid: tuple,
    elems_per_sub: tuple,
    kappa: float = 1.0,
    source: float = 1.0,
    dtype=np.float64,
    assemble_values: bool = True,
) -> FetiProblem:
    """Total-FETI decomposition of the structured heat problem (k = 1)."""
    return decompose_problem(
        "heat", dim, sub_grid, elems_per_sub, kappa=kappa, source=source,
        dtype=dtype, assemble_values=assemble_values)


def decompose_elasticity_problem(
    dim: int,
    sub_grid: tuple,
    elems_per_sub: tuple,
    lam: float = 1.0,
    mu: float = 1.0,
    body_force=None,
    dtype=np.float64,
    assemble_values: bool = True,
) -> FetiProblem:
    """Total-FETI decomposition of structured P1 linear elasticity
    (node-blocked vector DOFs, rigid-body kernels of dimension 3/6)."""
    return decompose_problem(
        "elasticity", dim, sub_grid, elems_per_sub, lam=lam, mu=mu,
        body_force=body_force, dtype=dtype, assemble_values=assemble_values)
