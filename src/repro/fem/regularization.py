"""Analytic (fixing-DOF) regularization of the SPSD subdomain matrices
(paper §2.2, [Brzobohatý et al. 2011]), for kernel dimension k ≥ 1.

Pick exactly k fixing DOFs such that the kernel basis restricted to those
rows, ``R_f = R[fixing_dofs]`` (k × k), is invertible, and add ρ to their
diagonal entries:

    K_reg = K + ρ Σ_{j ∈ fixing_dofs} e_j e_jᵀ

For any rhs ∈ range(K), ``K_reg⁻¹ rhs`` is an *exact* particular solution:
multiplying ``K_reg u = rhs`` by Rᵀ gives ``ρ R_fᵀ u_f = 0`` (both RᵀK u
and Rᵀ rhs vanish), and R_f invertible forces ``u_f = 0``, hence
``K u = rhs`` exactly. So ``K⁺ := K_reg⁻¹`` satisfies K K⁺ K = K — the
generalized-inverse property FETI needs from eq. (5). Because only
diagonal entries are touched, the stiffness sparsity pattern — and with it
the symbolic factorization — is unchanged.

Instances:
  * heat (k = 1, kernel = constants): one fixing node, the classic single
    ``K + ρ e_j e_jᵀ``.
  * 2D elasticity (k = 3): the 2D "3-2-1" fixture — both components of one
    node plus the y-component of a node at a different x.
  * 3D elasticity (k = 6): the 3-2-1 locating rule — all of node A, two of
    node B on the x-axis from A, one of node C off that axis.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "fixing_node_regularization",
    "fixing_dofs_regularization",
    "kernel_basis",
    "rigid_body_modes",
]


def fixing_dofs_regularization(K, fixing_dofs, rho: float | None = None):
    """Return K + ρ·Σ_j e_j e_jᵀ over the k fixing DOFs (numpy or jax)."""
    fixing_dofs = np.atleast_1d(np.asarray(fixing_dofs, dtype=np.int64))
    if rho is None:
        if isinstance(K, np.ndarray):
            rho = float(np.mean(np.diag(K)))
        else:
            import jax.numpy as jnp

            rho = jnp.mean(jnp.diag(K))
    if isinstance(K, np.ndarray):
        K = K.copy()
        K[fixing_dofs, fixing_dofs] += rho
        return K
    return K.at[fixing_dofs, fixing_dofs].add(rho)


def fixing_node_regularization(K, fixing_node: int, rho: float | None = None):
    """The k = 1 (scalar heat) case: K + ρ·e_j e_jᵀ."""
    return fixing_dofs_regularization(K, [fixing_node], rho=rho)


def rigid_body_modes(coords: np.ndarray, dtype=np.float64) -> np.ndarray:
    """Raw (un-orthonormalized) rigid-body modes of a 2D/3D point cloud.

    Returns (n_nodes*d, k) in node-blocked DOF order: d translations plus
    1 (2D) or 3 (3D) infinitesimal rotations about the centroid.
    """
    coords = np.asarray(coords, dtype=dtype)
    nn, d = coords.shape
    x = coords - coords.mean(axis=0)  # centering only affects conditioning
    k = 3 if d == 2 else 6
    R = np.zeros((nn, d, k), dtype=dtype)
    for c in range(d):  # translations
        R[:, c, c] = 1.0
    if d == 2:
        R[:, 0, 2] = -x[:, 1]
        R[:, 1, 2] = x[:, 0]
    else:
        R[:, 0, 3] = -x[:, 1]
        R[:, 1, 3] = x[:, 0]
        R[:, 1, 4] = -x[:, 2]
        R[:, 2, 4] = x[:, 1]
        R[:, 0, 5] = x[:, 2]
        R[:, 2, 5] = -x[:, 0]
    return R.reshape(nn * d, k)


def _orthonormalize(R: np.ndarray) -> np.ndarray:
    """QR-orthonormalize columns with a deterministic sign convention
    (each column's largest-magnitude entry is positive)."""
    Q, _ = np.linalg.qr(R)
    for j in range(Q.shape[1]):
        col = Q[:, j]
        if col[np.argmax(np.abs(col))] < 0:
            Q[:, j] = -col
    return Q


def kernel_basis(n: int | None = None, problem: str = "heat",
                 coords: np.ndarray | None = None,
                 dtype=np.float64) -> np.ndarray:
    """Orthonormal basis of Ker(K_i) as an (n, k) column matrix.

    * ``problem="heat"``: the normalized constant — (n, 1), needs ``n``.
    * ``problem="elasticity"``: the rigid-body modes of the subdomain's
      nodes — (n_nodes*d, k) with k = 3 (2D) / 6 (3D), needs ``coords``.

    Both go through the same orthonormalization, so the heat column is
    exactly the familiar ``1/sqrt(n)`` constant.
    """
    if problem == "heat":
        if n is None:
            raise ValueError("heat kernel_basis needs n")
        raw = np.ones((n, 1), dtype=dtype)
    elif problem == "elasticity":
        if coords is None:
            raise ValueError("elasticity kernel_basis needs coords")
        raw = rigid_body_modes(coords, dtype=dtype)
    else:
        raise ValueError(f"unknown problem {problem!r}")
    return _orthonormalize(raw)
