"""Analytic (fixing-node) regularization of the SPSD subdomain matrices
(paper §2.2, [Brzobohatý et al. 2011]).

For the scalar heat problem the kernel of each floating subdomain matrix is
the constant vector, so a single fixing node suffices:

    K_reg = K + ρ e_j e_jᵀ

For any rhs ∈ range(K), ``K_reg⁻¹ rhs`` is an *exact* particular solution
(K_reg r ∝ e_j for kernel vector r, hence e_jᵀ K_reg⁻¹ rhs = rᵀ rhs / ρ' = 0),
which makes ``K⁺ := K_reg⁻¹`` an exact generalized inverse (K K⁺ K = K) —
the property FETI needs from eq. (5).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["fixing_node_regularization", "kernel_basis"]


def fixing_node_regularization(K, fixing_node: int, rho: float | None = None):
    """Return K + ρ·e_j e_jᵀ (works for numpy and jax arrays)."""
    if rho is None:
        if isinstance(K, np.ndarray):
            rho = float(np.mean(np.diag(K)))
        else:
            rho = jnp.mean(jnp.diag(K))
    if isinstance(K, np.ndarray):
        K = K.copy()
        K[fixing_node, fixing_node] += rho
        return K
    return K.at[fixing_node, fixing_node].add(rho)


def kernel_basis(n: int, dtype=np.float64) -> np.ndarray:
    """Orthonormal basis of Ker(K_i) for the heat problem: the constant."""
    return np.full((n, 1), 1.0 / np.sqrt(n), dtype=dtype)
