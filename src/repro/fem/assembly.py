"""P1 finite-element assembly for the scalar heat-transfer (Laplace)
problem and vector-valued linear elasticity.

Element stiffness and scatter-assembly are implemented in JAX (vectorized
over elements); a scipy CSR path exists only as the reference oracle for
validating the FETI solve against an undecomposed global solve.

Vector problems use node-blocked DOF numbering: DOF ``node * d + c`` is
component ``c`` of ``node`` (d = 2 or 3 components per node). The scatter
assemblers are index-generic, so both problems share them through
:func:`element_dofs`.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sps

__all__ = [
    "p1_element_stiffness",
    "p1_elasticity_stiffness",
    "elasticity_matrix",
    "element_dofs",
    "load_vector",
    "elasticity_load_vector",
    "assemble_dense",
    "assemble_scipy_csr",
]


def _p1_gradients(coords, elems, dtype=jnp.float64):
    """Barycentric shape-function gradients and volumes, per element.

    For a simplex with vertices p0..pd, ``g_j = rows of inv(D)`` for j>=1
    (``D[:, j-1] = p_j - p_0``) and ``g_0 = -sum_j g_j``.

    Returns ``(G, vol)`` with G: (n_elems, d+1, d) and vol: (n_elems,).
    """
    coords = jnp.asarray(coords, dtype=dtype)
    elems = jnp.asarray(elems)
    d = coords.shape[1]
    p = coords[elems]  # (ne, d+1, d)
    D = jnp.swapaxes(p[:, 1:, :] - p[:, :1, :], 1, 2)  # (ne, d, d)
    vol = jnp.abs(jnp.linalg.det(D)) / math.factorial(d)
    g_rest = jnp.linalg.inv(D)  # (ne, d, d); rows are g_1..g_d
    g0 = -jnp.sum(g_rest, axis=1, keepdims=True)  # (ne, 1, d)
    G = jnp.concatenate([g0, g_rest], axis=1)  # (ne, d+1, d)
    return G, vol


def p1_element_stiffness(coords, elems, kappa: float = 1.0, dtype=jnp.float64):
    """Per-element P1 heat stiffness ``Ke = kappa * vol * G Gᵀ``,
    vectorized over elements. Returns (n_elems, d+1, d+1)."""
    G, vol = _p1_gradients(coords, elems, dtype=dtype)
    return kappa * vol[:, None, None] * jnp.einsum("eid,ejd->eij", G, G)


def elasticity_matrix(dim: int, lam: float = 1.0, mu: float = 1.0,
                      dtype=jnp.float64):
    """Isotropic elasticity matrix C in Voigt notation (Lamé parameters).

    2D is plane strain (3 strain components: εxx, εyy, γxy); 3D has the
    full 6 (εxx, εyy, εzz, γxy, γyz, γxz). Shear rows use engineering
    strain, so the shear diagonal is μ.
    """
    if dim == 2:
        C = [[lam + 2 * mu, lam, 0.0],
             [lam, lam + 2 * mu, 0.0],
             [0.0, 0.0, mu]]
    elif dim == 3:
        C = [[lam + 2 * mu, lam, lam, 0, 0, 0],
             [lam, lam + 2 * mu, lam, 0, 0, 0],
             [lam, lam, lam + 2 * mu, 0, 0, 0],
             [0, 0, 0, mu, 0, 0],
             [0, 0, 0, 0, mu, 0],
             [0, 0, 0, 0, 0, mu]]
    else:
        raise ValueError("elasticity supports dim 2 or 3")
    return jnp.asarray(C, dtype=dtype)


def _strain_displacement(G):
    """Element strain-displacement matrices B: (ne, n_strain, (d+1)*d).

    Node-blocked column order (node-major, component-minor), matching
    :func:`element_dofs`. Constant per element for P1.
    """
    ne, d1, d = G.shape
    if d == 2:
        # rows: εxx, εyy, γxy
        B = jnp.zeros((ne, 3, d1 * 2), G.dtype)
        for a in range(d1):
            gx, gy = G[:, a, 0], G[:, a, 1]
            B = B.at[:, 0, 2 * a + 0].set(gx)
            B = B.at[:, 1, 2 * a + 1].set(gy)
            B = B.at[:, 2, 2 * a + 0].set(gy)
            B = B.at[:, 2, 2 * a + 1].set(gx)
    else:
        # rows: εxx, εyy, εzz, γxy, γyz, γxz
        B = jnp.zeros((ne, 6, d1 * 3), G.dtype)
        for a in range(d1):
            gx, gy, gz = G[:, a, 0], G[:, a, 1], G[:, a, 2]
            B = B.at[:, 0, 3 * a + 0].set(gx)
            B = B.at[:, 1, 3 * a + 1].set(gy)
            B = B.at[:, 2, 3 * a + 2].set(gz)
            B = B.at[:, 3, 3 * a + 0].set(gy)
            B = B.at[:, 3, 3 * a + 1].set(gx)
            B = B.at[:, 4, 3 * a + 1].set(gz)
            B = B.at[:, 4, 3 * a + 2].set(gy)
            B = B.at[:, 5, 3 * a + 0].set(gz)
            B = B.at[:, 5, 3 * a + 2].set(gx)
    return B


def p1_elasticity_stiffness(coords, elems, lam: float = 1.0, mu: float = 1.0,
                            dtype=jnp.float64):
    """Per-element P1 linear-elasticity stiffness ``Ke = vol * Bᵀ C B``.

    Returns (n_elems, (d+1)*d, (d+1)*d) in node-blocked DOF order; scatter
    with ``element_dofs(elems, d)`` through the same assemblers as heat.
    """
    G, vol = _p1_gradients(coords, elems, dtype=dtype)
    d = G.shape[2]
    C = elasticity_matrix(d, lam, mu, dtype=G.dtype)
    B = _strain_displacement(G)
    return vol[:, None, None] * jnp.einsum("esi,st,etj->eij", B, C, B)


def element_dofs(elems, ndof_per_node: int) -> np.ndarray:
    """Expand node connectivity (ne, d+1) to DOF connectivity
    (ne, (d+1)*ndpn) in node-blocked order (DOF = node*ndpn + c)."""
    elems = np.asarray(elems)
    if ndof_per_node == 1:
        return elems
    return (elems[:, :, None] * ndof_per_node
            + np.arange(ndof_per_node)).reshape(elems.shape[0], -1)


def load_vector(coords, elems, n_nodes: int, source: float = 1.0,
                dtype=jnp.float64):
    """Consistent P1 load vector for a constant source term."""
    coords = jnp.asarray(coords, dtype=dtype)
    elems_j = jnp.asarray(elems)
    d = coords.shape[1]
    p = coords[elems_j]
    D = jnp.swapaxes(p[:, 1:, :] - p[:, :1, :], 1, 2)
    vol = jnp.abs(jnp.linalg.det(D)) / math.factorial(d)
    contrib = (source / (d + 1)) * vol  # per vertex of each element
    f = jnp.zeros((n_nodes,), dtype=dtype)
    for v in range(d + 1):
        f = f.at[elems_j[:, v]].add(contrib)
    return f


def elasticity_load_vector(coords, elems, n_nodes: int, body_force,
                           dtype=jnp.float64):
    """Consistent P1 load for a constant body force (d components).

    Returns the (n_nodes * d,) node-blocked DOF load vector.
    """
    body_force = jnp.asarray(body_force, dtype=dtype)
    d = len(body_force)
    comps = [load_vector(coords, elems, n_nodes, source=float(body_force[c]),
                         dtype=dtype) for c in range(d)]
    return jnp.stack(comps, axis=1).reshape(n_nodes * d)


def assemble_dense(n_dofs: int, elems, Ke, dtype=None):
    """Scatter per-element stiffness into a dense (n, n) matrix (JAX).

    ``elems`` is any per-element index array (node connectivity for scalar
    problems, :func:`element_dofs` output for vector problems).
    """
    elems_j = jnp.asarray(elems)
    Ke = jnp.asarray(Ke)
    d1 = elems_j.shape[1]
    rows = jnp.repeat(elems_j, d1, axis=1).reshape(-1)
    cols = jnp.tile(elems_j, (1, d1)).reshape(-1)
    vals = Ke.reshape(-1)
    K = jnp.zeros((n_dofs, n_dofs), dtype=dtype or Ke.dtype)
    return K.at[rows, cols].add(vals)


def assemble_scipy_csr(n_dofs: int, elems, Ke) -> sps.csr_matrix:
    """Reference-oracle CSR assembly (host-side, used in tests only)."""
    elems = np.asarray(elems)
    Ke = np.asarray(Ke)
    d1 = elems.shape[1]
    rows = np.repeat(elems, d1, axis=1).reshape(-1)
    cols = np.tile(elems, (1, d1)).reshape(-1)
    K = sps.coo_matrix((Ke.reshape(-1), (rows, cols)), shape=(n_dofs, n_dofs))
    return K.tocsr()
