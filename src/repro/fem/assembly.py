"""P1 finite-element assembly for the heat-transfer (Laplace) problem.

Element stiffness and scatter-assembly are implemented in JAX (vectorized
over elements); a scipy CSR path exists only as the reference oracle for
validating the FETI solve against an undecomposed global solve.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sps

__all__ = [
    "p1_element_stiffness",
    "load_vector",
    "assemble_dense",
    "assemble_scipy_csr",
]


def p1_element_stiffness(coords, elems, kappa: float = 1.0, dtype=jnp.float64):
    """Per-element P1 stiffness matrices, vectorized over elements.

    For a simplex with vertices p0..pd, barycentric gradients are
    ``g_j = rows of inv(D)`` for j>=1 (``D[:, j-1] = p_j - p_0``) and
    ``g_0 = -sum_j g_j``; then ``Ke = kappa * vol * G Gᵀ``.

    Returns (n_elems, d+1, d+1).
    """
    coords = jnp.asarray(coords, dtype=dtype)
    elems = jnp.asarray(elems)
    d = coords.shape[1]
    p = coords[elems]  # (ne, d+1, d)
    D = jnp.swapaxes(p[:, 1:, :] - p[:, :1, :], 1, 2)  # (ne, d, d)
    det = jnp.linalg.det(D)
    vol = jnp.abs(det) / math.factorial(d)
    Dinv = jnp.linalg.inv(D)  # (ne, d, d); rows of Dinv are g_1..g_d
    g_rest = Dinv  # (ne, d, d)
    g0 = -jnp.sum(g_rest, axis=1, keepdims=True)  # (ne, 1, d)
    G = jnp.concatenate([g0, g_rest], axis=1)  # (ne, d+1, d)
    Ke = kappa * vol[:, None, None] * jnp.einsum("eid,ejd->eij", G, G)
    return Ke


def load_vector(coords, elems, n_nodes: int, source: float = 1.0,
                dtype=jnp.float64):
    """Consistent P1 load vector for a constant source term."""
    coords = jnp.asarray(coords, dtype=dtype)
    elems_j = jnp.asarray(elems)
    d = coords.shape[1]
    p = coords[elems_j]
    D = jnp.swapaxes(p[:, 1:, :] - p[:, :1, :], 1, 2)
    vol = jnp.abs(jnp.linalg.det(D)) / math.factorial(d)
    contrib = (source / (d + 1)) * vol  # per vertex of each element
    f = jnp.zeros((n_nodes,), dtype=dtype)
    for v in range(d + 1):
        f = f.at[elems_j[:, v]].add(contrib)
    return f


def assemble_dense(n_nodes: int, elems, Ke, dtype=None):
    """Scatter per-element stiffness into a dense (n, n) matrix (JAX)."""
    elems_j = jnp.asarray(elems)
    Ke = jnp.asarray(Ke)
    d1 = elems_j.shape[1]
    rows = jnp.repeat(elems_j, d1, axis=1).reshape(-1)
    cols = jnp.tile(elems_j, (1, d1)).reshape(-1)
    vals = Ke.reshape(-1)
    K = jnp.zeros((n_nodes, n_nodes), dtype=dtype or Ke.dtype)
    return K.at[rows, cols].add(vals)


def assemble_scipy_csr(n_nodes: int, elems, Ke) -> sps.csr_matrix:
    """Reference-oracle CSR assembly (host-side, used in tests only)."""
    elems = np.asarray(elems)
    Ke = np.asarray(Ke)
    d1 = elems.shape[1]
    rows = np.repeat(elems, d1, axis=1).reshape(-1)
    cols = np.tile(elems, (1, d1)).reshape(-1)
    K = sps.coo_matrix((Ke.reshape(-1), (rows, cols)), shape=(n_nodes, n_nodes))
    return K.tocsr()
