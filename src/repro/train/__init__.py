"""Training & serving substrate: optimizer, LM loss, train_step with
gradient accumulation, prefill/decode serve steps."""
from repro.train.optimizer import OptimizerConfig, adamw_init, adamw_update
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import TrainConfig, loss_fn, make_train_step

__all__ = [
    "OptimizerConfig",
    "TrainConfig",
    "adamw_init",
    "adamw_update",
    "loss_fn",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
]
