"""AdamW with dtype-configurable moments + cosine schedule + global clip.

Moment dtype matters at scale: bf16 moments halve optimizer memory, which
is what lets nemotron-4-340b fit 256 × 16 GB chips fully sharded (see
DESIGN.md §6); ≥100B configs default to bf16 moments.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "adamw_init", "adamw_update", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" for >=100B configs


def cosine_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.learning_rate * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params, cfg: OptimizerConfig) -> dict:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def zeros(p):
        return jnp.zeros(p.shape, mdt)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state: dict, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(mdt),
            v32.astype(mdt),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
