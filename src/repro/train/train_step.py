"""The training step: LM loss (CE + z-loss + MoE aux), grad, microbatched
gradient accumulation, optional gradient compression hook, AdamW update.

The same step serves decoder LMs (next-token), the encoder-only audio arch
(per-frame classification — labels provided by the pipeline) and the VLM
backbone (vision positions/embeddings in the batch dict).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig
from repro.train.optimizer import OptimizerConfig, adamw_update

__all__ = ["TrainConfig", "loss_fn", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    remat: bool = True
    grad_accum: int = 1  # microbatches per step
    accum_dtype: str = "float32"  # grad accumulator; "bfloat16" halves the
    #                               buffer for >=100B configs (16 GB HBM)
    z_loss_coef: float = 1e-4
    grad_transform: Optional[Callable] = None  # e.g. compression (distributed/)
    attn_args: Optional[dict] = None  # chunk sizes / skip_masked_blocks


def loss_fn(params, cfg: ModelConfig, batch: dict, tcfg: TrainConfig):
    """Mean CE over non-masked tokens (+ z-loss + MoE aux)."""
    logits, _, aux = forward(params, cfg, batch, remat=tcfg.remat,
                             attn_args=tcfg.attn_args)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / denom
    zl = tcfg.z_loss_coef * (jnp.square(lse) * mask).sum() / denom
    total = loss + zl + aux
    return total, {"ce": loss, "z_loss": zl, "moe_aux": aux}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). With grad_accum>1 the batch's leading axis is split into
    microbatches accumulated via lax.scan (activation memory / global batch
    trade-off — a §Perf knob)."""

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, tcfg), has_aux=True
    )

    def accum_grads(params, batch):
        if tcfg.grad_accum <= 1:
            (loss, parts), grads = grad_fn(params, batch)
            return loss, parts, grads

        def micro(b):
            B = b.shape[0] if hasattr(b, "shape") else None
            return b.reshape((tcfg.grad_accum, B // tcfg.grad_accum)
                             + b.shape[1:])

        mb = jax.tree.map(micro, batch)

        def body(carry, m):
            acc, loss_acc = carry
            (loss, _), g = grad_fn(params, m)
            acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
            return (acc, loss_acc + loss), None

        adt = jnp.bfloat16 if tcfg.accum_dtype == "bfloat16" else jnp.float32
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        (gsum, loss_sum), _ = jax.lax.scan(
            body, (zero, jnp.zeros((), jnp.float32)), mb
        )
        scale = 1.0 / tcfg.grad_accum
        grads = jax.tree.map(lambda g: g * scale, gsum)
        return loss_sum * scale, {}, grads

    def train_step(params, opt_state, batch):
        loss, parts, grads = accum_grads(params, batch)
        if tcfg.grad_transform is not None:
            grads = tcfg.grad_transform(grads)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             tcfg.optimizer)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step
