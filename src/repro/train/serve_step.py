"""Serving steps: prefill (build the cache from a prompt batch) and decode
(one new token against the cache) — the two inference shapes of the
assigned grid (prefill_32k lowers the prefill step, decode_32k / long_500k
lower the decode step)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import forward, init_cache
from repro.models.config import ModelConfig

__all__ = ["make_prefill_step", "make_decode_step", "greedy_generate"]


def make_prefill_step(cfg: ModelConfig, attn_args: dict | None = None):
    """prefill(params, batch, cache) -> (last_logits, cache)."""

    def prefill(params, batch, cache):
        logits, cache, _ = forward(
            params, cfg, batch, cache=cache,
            cache_index=jnp.asarray(0, jnp.int32), attn_args=attn_args,
            last_only=True,
        )
        return logits[:, -1, :], cache

    return prefill


def make_decode_step(cfg: ModelConfig, attn_args: dict | None = None):
    """decode(params, tokens (B,1), cache, index) -> (logits (B,V), cache)."""

    def decode(params, tokens, cache, index):
        logits, cache, _ = forward(
            params, cfg, {"tokens": tokens}, cache=cache, cache_index=index,
            attn_args=attn_args,
        )
        return logits[:, 0, :], cache

    return decode


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    steps: int, max_len: int | None = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Greedy decoding loop (examples/serving driver). Returns
    (generated (B, steps), logits of last step)."""
    B, S = prompt.shape
    max_len = max_len or (S + steps)
    cache = init_cache(cfg, B, max_len)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    logits, cache = prefill(params, {"tokens": prompt}, cache)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for t in range(steps):
        out.append(tok)
        if t == steps - 1:
            break
        logits, cache = decode(params, tok, cache,
                               jnp.asarray(S + t, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1), logits
