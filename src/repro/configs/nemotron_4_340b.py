"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        family="dense",
        num_layers=96,
        d_model=18432,
        d_ff=73728,
        vocab_size=256_000,
        num_heads=96,
        num_kv_heads=8,
        head_dim=192,
        attn_kind="gqa",
        mlp_kind="squared_relu",
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        d_ff=192,
        vocab_size=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=24,
        attn_kind="gqa",
        mlp_kind="squared_relu",
        dtype="float32",
        param_dtype="float32",
    )


register("nemotron-4-340b", config, smoke_config)
