"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision frontend is a stub (input_specs provides
precomputed patch embeddings merged at the embedding layer). head_dim=128,
M-RoPE half-dim 64 split (t,h,w) = (16, 24, 24) as in the released model.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        d_ff=8960,
        vocab_size=151936,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        attn_kind="gqa",
        qkv_bias=True,
        pos_emb="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        mlp_kind="swiglu",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        attn_kind="gqa",
        qkv_bias=True,
        pos_emb="mrope",
        mrope_sections=(2, 3, 3),
        mlp_kind="swiglu",
        tie_embeddings=True,
        dtype="float32",
        param_dtype="float32",
    )


register("qwen2-vl-2b", config, smoke_config)
