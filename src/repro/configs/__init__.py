"""Assigned-architecture configs (exact public-literature numbers) plus the
paper's own FETI problems, all selectable via --arch <id>."""
from repro.configs.registry import (
    FetiArchConfig,
    get_config,
    get_smoke_config,
    list_archs,
    register,
)

__all__ = ["FetiArchConfig", "get_config", "get_smoke_config", "list_archs",
           "register"]
