"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        num_layers=88,
        d_model=12288,
        d_ff=28672,
        vocab_size=32768,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        attn_kind="gqa",
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        attn_kind="gqa",
        mlp_kind="swiglu",
        dtype="float32",
        param_dtype="float32",
    )


register("mistral-large-123b", config, smoke_config)
