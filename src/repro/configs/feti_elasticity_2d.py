"""feti-elasticity-2d — the paper's target engineering workload: 2D linear
elasticity (plane strain, 2 DOFs per node) on the unit square, uniform
triangles, total-FETI with rigid-body-mode kernels (k = 3). The companion
CUDA work (Homola et al., arXiv:2502.08382) benchmarks exactly this
setting in ESPRESO."""
from repro.configs.registry import FetiArchConfig, register


def config() -> FetiArchConfig:
    # 4x4 subdomains of 32x32 elements (~2.2k DOFs each: the node-blocked
    # 2-DOF expansion of a ~1.1k-node heat subdomain)
    return FetiArchConfig(
        name="feti-elasticity-2d",
        dim=2,
        sub_grid=(4, 4),
        elems_per_sub=(32, 32),
        block_size=128,
        rhs_block_size=128,
        trsm_variant="factor_split",
        syrk_variant="input_split",
        problem="elasticity",
    )


def smoke_config() -> FetiArchConfig:
    return FetiArchConfig(
        name="feti-elasticity-2d-smoke",
        dim=2,
        sub_grid=(2, 2),
        elems_per_sub=(4, 4),
        block_size=8,
        rhs_block_size=8,
        problem="elasticity",
    )


register("feti-elasticity-2d", config, smoke_config)
