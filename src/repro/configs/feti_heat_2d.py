"""feti-heat-2d — the paper's own benchmark problem (§4): 2D heat transfer
on the unit square, uniform triangles, total-FETI decomposition, SC
assembly with the sparsity-utilizing pipeline."""
from repro.configs.registry import FetiArchConfig, register


def config() -> FetiArchConfig:
    # production-scale cluster slice: 8x8 subdomains of 64x64 elements
    # (~4.2k unknowns each; paper sweeps 1k..70k)
    return FetiArchConfig(
        name="feti-heat-2d",
        dim=2,
        sub_grid=(8, 8),
        elems_per_sub=(64, 64),
        block_size=128,
        rhs_block_size=128,
        trsm_variant="factor_split",
        syrk_variant="input_split",
    )


def smoke_config() -> FetiArchConfig:
    return FetiArchConfig(
        name="feti-heat-2d-smoke",
        dim=2,
        sub_grid=(2, 2),
        elems_per_sub=(4, 4),
        block_size=8,
        rhs_block_size=8,
    )


register("feti-heat-2d", config, smoke_config)
