"""feti-elasticity-3d — 3D linear elasticity (3 DOFs per node) on the unit
cube, uniform tetrahedra, total-FETI with 6-dimensional rigid-body-mode
kernels: the hardest coarse-space setting the paper's pipeline targets,
and the natural stress case for the node-blocked packed factor storage."""
from repro.configs.registry import FetiArchConfig, register


def config() -> FetiArchConfig:
    # 2x2x2 subdomains of 8^3 elements (~2.2k DOFs each)
    return FetiArchConfig(
        name="feti-elasticity-3d",
        dim=3,
        sub_grid=(2, 2, 2),
        elems_per_sub=(8, 8, 8),
        block_size=128,
        rhs_block_size=128,
        trsm_variant="factor_split",
        syrk_variant="input_split",
        problem="elasticity",
    )


def smoke_config() -> FetiArchConfig:
    return FetiArchConfig(
        name="feti-elasticity-3d-smoke",
        dim=3,
        sub_grid=(2, 2, 1),
        elems_per_sub=(2, 2, 2),
        block_size=8,
        rhs_block_size=8,
        problem="elasticity",
    )


register("feti-elasticity-3d", config, smoke_config)
