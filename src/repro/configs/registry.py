"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each config module registers a full-size ModelConfig (exact public-
literature numbers) and a reduced smoke ModelConfig (same family/topology,
tiny dims) used by the CPU smoke tests. The paper's own FETI problem
registers through the same mechanism with a FetiArchConfig.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

__all__ = ["register", "get_config", "get_smoke_config", "list_archs",
           "FetiArchConfig", "ARCH_MODULES"]

_FULL: Dict[str, Callable] = {}
_SMOKE: Dict[str, Callable] = {}

ARCH_MODULES = [
    "qwen2_vl_2b",
    "granite_3_8b",
    "nemotron_4_340b",
    "qwen15_32b",
    "mistral_large_123b",
    "recurrentgemma_2b",
    "rwkv6_1_6b",
    "grok_1_314b",
    "deepseek_v2_236b",
    "hubert_xlarge",
    "feti_heat_2d",
    "feti_heat_3d",
    "feti_elasticity_2d",
    "feti_elasticity_3d",
]


@dataclasses.dataclass(frozen=True)
class FetiArchConfig:
    """The paper's own 'architecture': a structured FETI problem.

    ``problem`` selects the workload: scalar "heat" (1 DOF/node, kernel
    dim 1) or vector "elasticity" (2-3 DOFs/node, rigid-body kernel dim
    3/6 — the paper's target engineering setting)."""

    name: str
    dim: int
    sub_grid: Tuple[int, ...]
    elems_per_sub: Tuple[int, ...]
    block_size: int = 128
    rhs_block_size: int = 128
    trsm_variant: str = "factor_split"
    syrk_variant: str = "input_split"
    problem: str = "heat"
    family: str = "feti"


def register(name: str, full: Callable, smoke: Callable) -> None:
    _FULL[name] = full
    _SMOKE[name] = smoke


def _ensure_loaded() -> None:
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    _ensure_loaded()
    if name not in _FULL:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_FULL)}")
    return _FULL[name]()


def get_smoke_config(name: str):
    _ensure_loaded()
    return _SMOKE[name]()


def list_archs(family: Optional[str] = None) -> list[str]:
    _ensure_loaded()
    names = sorted(_FULL)
    if family is None:
        return names
    return [n for n in names if getattr(_FULL[n](), "family", None) == family]
