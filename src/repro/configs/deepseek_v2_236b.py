"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400 — MLA (kv_lora=512, q_lora=1536, nope=128, rope=64, v=128),
MoE 2 shared + 160 routed top-6, first layer dense (d_ff=12288)
[arXiv:2405.04434; hf]."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        d_ff=12288,  # dense first layer
        vocab_size=102400,
        num_heads=128,
        attn_kind="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        mlp_kind="swiglu",
        num_experts=160,
        num_shared_experts=2,
        top_k=6,
        moe_d_ff=1536,
        first_dense_layers=1,
        capacity_factor=1.0,
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=4,
        attn_kind="mla",
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
        mlp_kind="swiglu",
        num_experts=8,
        num_shared_experts=2,
        top_k=2,
        moe_d_ff=32,
        first_dense_layers=1,
        capacity_factor=2.0,
        dtype="float32",
        param_dtype="float32",
    )


register("deepseek-v2-236b", config, smoke_config)
