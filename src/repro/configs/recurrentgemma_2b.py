"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (rglru, rglru, attn) 1:2
[arXiv:2402.19427; hf]. Sub-quadratic: runs the long_500k shape (local
window 2048 ring cache + O(1) recurrent state)."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        d_ff=7680,
        vocab_size=256_000,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        attn_kind="gqa",
        layer_pattern=("rglru", "rglru", "attn"),
        local_window=2048,
        lru_width=2560,
        conv_width=4,
        mlp_kind="geglu",
        pos_emb="rope",
        rope_theta=10_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke",
        family="hybrid",
        num_layers=3,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        attn_kind="gqa",
        layer_pattern=("rglru", "rglru", "attn"),
        local_window=16,
        lru_width=64,
        conv_width=4,
        mlp_kind="geglu",
        tie_embeddings=True,
        dtype="float32",
        param_dtype="float32",
    )


register("recurrentgemma-2b", config, smoke_config)
