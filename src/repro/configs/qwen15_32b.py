"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40 => MHA) d_ff=27392
vocab=152064 — QKV bias [hf:Qwen; hf]."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        d_ff=27392,
        vocab_size=152064,
        num_heads=40,
        num_kv_heads=40,
        head_dim=128,
        attn_kind="gqa",
        qkv_bias=True,
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
        # MHA (kv=40): the bf16 decode_32k cache alone is 5.5 TB > fleet
        # HBM; fp8 KV cache halves it under the 16 GB/chip budget.
        cache_dtype="float8_e4m3fn",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        attn_kind="gqa",
        qkv_bias=True,
        mlp_kind="swiglu",
        dtype="float32",
        param_dtype="float32",
    )


register("qwen1.5-32b", config, smoke_config)
