"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
— encoder-only (bidirectional), masked-frame classification head
[arXiv:2106.07447]. The conv waveform frontend is a stub: input_specs
provides precomputed frame embeddings. No decode shapes (encoder-only)."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        d_ff=5120,
        vocab_size=504,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        attn_kind="gqa",
        causal=False,
        pos_emb="none",  # conv positional frontend is part of the stub
        mlp_kind="gelu",
        mlp_bias=True,
        norm="layernorm",
        frontend_stub=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=32,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        attn_kind="gqa",
        causal=False,
        pos_emb="none",
        mlp_kind="gelu",
        mlp_bias=True,
        norm="layernorm",
        frontend_stub=True,
        dtype="float32",
        param_dtype="float32",
    )


register("hubert-xlarge", config, smoke_config)
