"""feti-heat-3d — the paper's own benchmark problem (§4): 3D heat transfer
on the unit cube, uniform tetrahedra, total-FETI decomposition. 3D is where
the paper reports its headline speedups (5.1x kernel / 3.3x assembly)."""
from repro.configs.registry import FetiArchConfig, register


def config() -> FetiArchConfig:
    # 4x4x4 subdomains of 16^3 elements (~4.9k unknowns each)
    return FetiArchConfig(
        name="feti-heat-3d",
        dim=3,
        sub_grid=(4, 4, 4),
        elems_per_sub=(16, 16, 16),
        block_size=128,
        rhs_block_size=128,
        trsm_variant="factor_split",
        syrk_variant="input_split",
    )


def smoke_config() -> FetiArchConfig:
    return FetiArchConfig(
        name="feti-heat-3d-smoke",
        dim=3,
        sub_grid=(2, 2, 1),
        elems_per_sub=(3, 3, 3),
        block_size=8,
        rhs_block_size=8,
    )


register("feti-heat-3d", config, smoke_config)
