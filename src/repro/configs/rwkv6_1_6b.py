"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch, data-dependent decay [arXiv:2404.05892].
Sub-quadratic: runs the long_500k shape (O(1) matrix state per layer)."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        d_ff=7168,
        vocab_size=65536,
        attn_kind="none",
        layer_pattern=("rwkv6",),
        rwkv_head_dim=64,
        pos_emb="none",
        norm="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        attn_kind="none",
        layer_pattern=("rwkv6",),
        rwkv_head_dim=16,
        pos_emb="none",
        norm="layernorm",
        dtype="float32",
        param_dtype="float32",
    )


register("rwkv6-1.6b", config, smoke_config)
