"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA [hf:ibm-granite; hf]."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        d_ff=12800,
        vocab_size=49155,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        attn_kind="gqa",
        mlp_kind="swiglu",
        rope_theta=10_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        attn_kind="gqa",
        mlp_kind="swiglu",
        tie_embeddings=True,
        dtype="float32",
        param_dtype="float32",
    )


register("granite-3-8b", config, smoke_config)
