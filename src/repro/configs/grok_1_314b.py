"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.configs.registry import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        d_ff=32768,
        vocab_size=131072,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        attn_kind="gqa",
        mlp_kind="swiglu",
        num_experts=8,
        top_k=2,
        moe_d_ff=32768,
        capacity_factor=1.25,
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        attn_kind="gqa",
        mlp_kind="swiglu",
        num_experts=4,
        top_k=2,
        moe_d_ff=64,
        capacity_factor=2.0,
        dtype="float32",
        param_dtype="float32",
    )


register("grok-1-314b", config, smoke_config)
