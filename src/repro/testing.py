"""Shared test/benchmark fixtures: random sparse SPD systems and FETI-like
gluing patterns with controllable stepped structure."""
from __future__ import annotations

import numpy as np

__all__ = [
    "random_banded_spd",
    "random_lower_banded",
    "random_feti_like_bt",
    "block_fill_mask_from_factor",
]


def random_banded_spd(n: int, bandwidth: int, rng: np.random.Generator,
                      dtype=np.float64) -> np.ndarray:
    """Random well-conditioned SPD matrix with the given (half-)bandwidth."""
    A = np.zeros((n, n), dtype=dtype)
    for d in range(bandwidth + 1):
        v = rng.standard_normal(n - d).astype(dtype) * (0.5 ** d)
        A += np.diag(v, -d)
    A = A @ A.T
    A += np.eye(n, dtype=dtype) * (np.trace(A) / n * 0.1 + 1.0)
    return A


def random_lower_banded(n: int, bandwidth: int, rng: np.random.Generator,
                        fill: float = 0.5, dtype=np.float64) -> np.ndarray:
    """Random nonsingular lower-triangular factor with banded sparsity."""
    L = np.zeros((n, n), dtype=dtype)
    for i in range(n):
        lo = max(0, i - bandwidth)
        row = rng.standard_normal(i - lo).astype(dtype)
        row *= rng.random(i - lo) < fill
        L[i, lo:i] = row * 0.3
        L[i, i] = 1.0 + rng.random()
    return L


def random_feti_like_bt(n: int, m: int, rng: np.random.Generator,
                        nnz_per_col: int = 2, spread: int = 4,
                        dtype=np.float64) -> np.ndarray:
    """Random B̃ᵀ: each column has a few ±1 entries clustered around a random
    anchor row — mimics FETI gluing where each Lagrange multiplier touches a
    couple of interface DOFs. Column pivots end up roughly uniform over rows
    (the property the paper needs from the fill-reducing ordering)."""
    Bt = np.zeros((n, m), dtype=dtype)
    anchors = rng.integers(0, n, size=m)
    for j in range(m):
        a = int(anchors[j])
        rows = np.clip(a + rng.integers(0, spread + 1, size=nnz_per_col), 0, n - 1)
        for r in np.unique(rows):
            Bt[r, j] = rng.choice([-1.0, 1.0])
    return Bt


def block_fill_mask_from_factor(L: np.ndarray, block_size: int) -> np.ndarray:
    """Lower-triangular block fill mask: True where an L block has any nnz."""
    n = L.shape[0]
    nb = -(-n // block_size)
    mask = np.zeros((nb, nb), dtype=bool)
    for i in range(nb):
        i0, i1 = i * block_size, min((i + 1) * block_size, n)
        for k in range(i + 1):
            k0, k1 = k * block_size, min((k + 1) * block_size, n)
            mask[i, k] = np.any(L[i0:i1, k0:k1] != 0)
    return mask
