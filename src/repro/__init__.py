"""repro: sparsity-utilizing Schur complement assembly for domain
decomposition (Homola et al., CS.DC 2025) as a multi-pod JAX/Pallas
framework. See README.md for the map and DESIGN.md for the design."""

__version__ = "1.0.0"
