"""Memmap token-file pipeline: flat binary corpus -> sharded, shuffled,
fixed-length LM batches. Per-host sharding keys off (host_id, num_hosts) so
every host reads a disjoint stream — the multi-node data path."""
from __future__ import annotations

from typing import Iterator

import numpy as np

__all__ = ["write_token_file", "TokenFileDataset"]

_MAGIC = np.uint32(0x52503031)  # "RP01"


def write_token_file(path: str, tokens: np.ndarray) -> None:
    tokens = np.asarray(tokens)
    assert tokens.ndim == 1
    dtype = np.uint32 if tokens.max(initial=0) >= 2**16 else np.uint16
    with open(path, "wb") as f:
        header = np.array(
            [_MAGIC, np.uint32(1 if dtype == np.uint16 else 2),
             np.uint32(len(tokens) & 0xFFFFFFFF),
             np.uint32(len(tokens) >> 32)], np.uint32,
        )
        f.write(header.tobytes())
        f.write(tokens.astype(dtype).tobytes())


class TokenFileDataset:
    """Iterates (tokens, labels) windows from a flat token file."""

    def __init__(self, path: str, seq_len: int, batch_size: int,
                 host_id: int = 0, num_hosts: int = 1, seed: int = 0):
        header = np.fromfile(path, np.uint32, count=4)
        if header[0] != _MAGIC:
            raise ValueError(f"{path}: bad magic {header[0]:#x}")
        dtype = np.uint16 if header[1] == 1 else np.uint32
        count = int(header[2]) | (int(header[3]) << 32)
        self._data = np.memmap(path, dtype, mode="r", offset=16, shape=(count,))
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.seed = seed
        self.n_windows = (count - 1) // seq_len

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        order = rng.permutation(self.n_windows)
        order = order[self.host_id :: self.num_hosts]  # disjoint per host
        bs, sl = self.batch_size, self.seq_len
        for i in range(0, len(order) - bs + 1, bs):
            idx = order[i : i + bs]
            toks = np.stack([self._data[j * sl : j * sl + sl + 1] for j in idx])
            yield {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
            }
