"""Deterministic synthetic batches matching each architecture's input
contract (tokens / audio features / VLM merged embeddings + M-RoPE
positions). Used by the end-to-end examples, smoke tests and benchmarks."""
from __future__ import annotations

from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["synthetic_batch", "synthetic_batches"]


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                    step: int = 0) -> dict:
    """One deterministic batch. Learnable structure: tokens follow a noisy
    affine-recurrence over the vocab so a real model can reduce loss."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    V = cfg.vocab_size
    x = np.zeros((batch, seq + 1), np.int64)
    x[:, 0] = rng.integers(0, V, batch)
    mult = 31
    noise = rng.integers(0, 7, (batch, seq))
    for t in range(seq):
        x[:, t + 1] = (x[:, t] * mult + 17 + noise[:, t]) % V
    out = {
        "tokens": jnp.asarray(x[:, :seq], jnp.int32),
        "labels": jnp.asarray(x[:, 1 : seq + 1], jnp.int32),
    }
    if cfg.is_encoder_only:
        # encoder: per-frame targets, no shift
        out["labels"] = jnp.asarray(x[:, :seq] % V, jnp.int32)
    if cfg.frontend_stub and cfg.family == "audio":
        feats = rng.standard_normal((batch, seq, cfg.d_model)).astype(np.float32)
        out["features"] = jnp.asarray(feats)
    if cfg.family == "vlm":
        n_img = max(seq // 4, 1)
        vis = rng.standard_normal((batch, seq, cfg.d_model)).astype(np.float32)
        mask = np.zeros((batch, seq), bool)
        mask[:, :n_img] = True  # image tokens lead the sequence
        out["vision_embeds"] = jnp.asarray(vis)
        out["vision_mask"] = jnp.asarray(mask)
        # M-RoPE positions: image patch grid then text raster
        side = max(int(np.sqrt(n_img)), 1)
        t_pos = np.zeros((batch, seq), np.int32)
        h_pos = np.zeros((batch, seq), np.int32)
        w_pos = np.zeros((batch, seq), np.int32)
        for i in range(n_img):
            h_pos[:, i] = i // side
            w_pos[:, i] = i % side
        text_start = side  # text continues after the image grid
        for i in range(n_img, seq):
            t_pos[:, i] = text_start + (i - n_img)
            h_pos[:, i] = t_pos[:, i]
            w_pos[:, i] = t_pos[:, i]
        out["positions"] = jnp.asarray(
            np.stack([t_pos, h_pos, w_pos], axis=-1)
        )
    return out


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int,
                      seed: int = 0) -> Iterator[dict]:
    step = 0
    while True:
        yield synthetic_batch(cfg, batch, seq, seed=seed, step=step)
        step += 1
