"""Data substrate: deterministic synthetic batches for every assigned
architecture's input contract, and a memmap token-file pipeline with
per-host sharding for real corpora."""
from repro.data.synthetic import synthetic_batch, synthetic_batches
from repro.data.tokens import TokenFileDataset, write_token_file

__all__ = [
    "TokenFileDataset",
    "synthetic_batch",
    "synthetic_batches",
    "write_token_file",
]
