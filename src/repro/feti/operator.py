"""The FETI dual operator F = B K⁺ Bᵀ and friends, batched over subdomains.

Implicit application (paper eq. 11): SPMV + two TRSV + SPMV per subdomain.
Explicit application (paper eq. 12): one dense GEMV per subdomain against
the preassembled SC — the thing the whole paper exists to make cheap.

The gather (λ → local) / scatter-add (local → λ) pair is the algebraic form
of the paper's MPI neighbour exchange. These batched implementations are
also the per-shard bodies of the distributed deployment: under shard_map
the scatter lands in a device-local partial and becomes a psum over the
subdomain-sharded axis (see :mod:`repro.feti.sharded`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gather_local",
    "scatter_dual",
    "explicit_dual_apply",
    "implicit_dual_apply",
    "lumped_preconditioner",
    "dual_rhs",
]


def gather_local(lam: jax.Array, lambda_ids: jax.Array) -> jax.Array:
    """(n_lambda,) dual vector -> (S, m_max) local blocks (pad id reads 0)."""
    lam_ext = jnp.concatenate([lam, jnp.zeros((1,), lam.dtype)])
    return lam_ext[lambda_ids]


def scatter_dual(vals: jax.Array, lambda_ids: jax.Array, n_lambda: int) -> jax.Array:
    """(S, m_max) local blocks -> (n_lambda,) additive dual assembly."""
    out = jnp.zeros((n_lambda + 1,), vals.dtype)
    return out.at[lambda_ids].add(vals)[:-1]


def explicit_dual_apply(F: jax.Array, lambda_ids: jax.Array, n_lambda: int,
                        lam: jax.Array) -> jax.Array:
    """q = Σᵢ B̃ᵢᵀ-scatter( F̃ᵢ · gather(λ) )   (paper eq. 12)."""
    p_loc = gather_local(lam, lambda_ids)
    q_loc = jnp.einsum("sab,sb->sa", F, p_loc)
    return scatter_dual(q_loc, lambda_ids, n_lambda)


def _tri_solve(L, b, transpose):
    return jax.lax.linalg.triangular_solve(
        L, b[..., None], left_side=True, lower=True, transpose_a=transpose
    )[..., 0]


def implicit_dual_apply(L: jax.Array, Btp: jax.Array, lambda_ids: jax.Array,
                        n_lambda: int, lam: jax.Array) -> jax.Array:
    """q = Σᵢ scatter( B̃ᵢ L⁻ᵀL⁻¹ B̃ᵢᵀ gather(λ) )  (paper eq. 11)."""
    p_loc = gather_local(lam, lambda_ids)
    v = jnp.einsum("snm,sm->sn", Btp, p_loc)
    t = jax.vmap(_tri_solve, in_axes=(0, 0, None))(L, v, False)
    t = jax.vmap(_tri_solve, in_axes=(0, 0, None))(L, t, True)
    q_loc = jnp.einsum("snm,sn->sm", Btp, t)
    return scatter_dual(q_loc, lambda_ids, n_lambda)


def lumped_preconditioner(K: jax.Array, Bt: jax.Array, lambda_ids: jax.Array,
                          n_lambda: int, w: jax.Array) -> jax.Array:
    """Lumped FETI preconditioner: M⁻¹ ≈ Σᵢ B̃ᵢ Kᵢ B̃ᵢᵀ."""
    p_loc = gather_local(w, lambda_ids)
    v = jnp.einsum("snm,sm->sn", Bt, p_loc)
    v = jnp.einsum("snk,sk->sn", K, v)
    q_loc = jnp.einsum("snm,sn->sm", Bt, v)
    return scatter_dual(q_loc, lambda_ids, n_lambda)


def dual_rhs(L: jax.Array, Btp: jax.Array, fp: jax.Array,
             lambda_ids: jax.Array, n_lambda: int, c: jax.Array) -> jax.Array:
    """d = B K⁺ f − c (paper §2.1)."""
    t = jax.vmap(_tri_solve, in_axes=(0, 0, None))(L, fp, False)
    t = jax.vmap(_tri_solve, in_axes=(0, 0, None))(L, t, True)
    q_loc = jnp.einsum("snm,sn->sm", Btp, t)
    return scatter_dual(q_loc, lambda_ids, n_lambda) - c
