"""The FETI dual operator F = B K⁺ Bᵀ and friends, batched over subdomains.

Implicit application (paper eq. 11): SPMV + two TRSV + SPMV per subdomain.
Explicit application (paper eq. 12): one dense GEMV per subdomain against
the preassembled SC — the thing the whole paper exists to make cheap.

The gather (λ → local) / scatter-add (local → λ) pair is the algebraic form
of the paper's MPI neighbour exchange. These batched implementations are
also the per-shard bodies of the distributed deployment: under shard_map
the scatter lands in a device-local partial and becomes a psum over the
subdomain-sharded axis (see :mod:`repro.feti.sharded`).

Factor stacks may be dense ``(S, n, n)`` arrays or packed block-sparse
:class:`~repro.sparse.packed.PackedBlocks` stacks (``storage="packed"`` in
:class:`~repro.core.SchurAssemblyConfig`); :func:`solve_with_factor`
dispatches per representation so every operator below is storage-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.packed import (
    PackedBlocks,
    packed_symm_matvec,
    packed_tri_solve,
)

__all__ = [
    "gather_local",
    "scatter_dual",
    "local_dual_apply",
    "explicit_dual_apply",
    "explicit_dual_apply_many",
    "implicit_dual_apply",
    "implicit_dual_apply_many",
    "lumped_preconditioner",
    "lumped_preconditioner_many",
    "dirichlet_preconditioner",
    "dirichlet_preconditioner_many",
    "dual_rhs",
    "dual_rhs_many",
    "solve_with_factor",
    "solve_with_factor_many",
    "apply_stiffness",
    "apply_stiffness_many",
]


def gather_local(lam: jax.Array, lambda_ids: jax.Array) -> jax.Array:
    """(n_lambda,) dual vector -> (S, m_max) local blocks (pad id reads 0).

    Rank-generic: an (n_lambda, n_rhs) multiplier stack gathers to
    (S, m_max, n_rhs) — the same one-hot exchange applied per column.
    """
    lam_ext = jnp.concatenate(
        [lam, jnp.zeros((1,) + lam.shape[1:], lam.dtype)])
    return lam_ext[lambda_ids]


def scatter_dual(vals: jax.Array, lambda_ids: jax.Array, n_lambda: int) -> jax.Array:
    """(S, m_max) local blocks -> (n_lambda,) additive dual assembly.

    Rank-generic like :func:`gather_local`: (S, m_max, n_rhs) local column
    stacks scatter-add to (n_lambda, n_rhs).
    """
    out = jnp.zeros((n_lambda + 1,) + vals.shape[2:], vals.dtype)
    return out.at[lambda_ids].add(vals)[:-1]


def local_dual_apply(apply_local, lambda_ids: jax.Array, n_lambda: int,
                     lam: jax.Array) -> jax.Array:
    """The λ-space sandwich every dual-side operator shares:
    gather(λ) → per-subdomain local apply → scatter-add back into λ space.

    ``apply_local`` maps the (S, m_max) gathered local multiplier blocks to
    (S, m_max) results; the gather/scatter pair around it is the algebraic
    form of the paper's MPI neighbour exchange. The explicit dual operator
    and both preconditioners are instances — only the per-subdomain GEMV
    stack in the middle differs.
    """
    return scatter_dual(apply_local(gather_local(lam, lambda_ids)),
                        lambda_ids, n_lambda)


def explicit_dual_apply(F: jax.Array, lambda_ids: jax.Array, n_lambda: int,
                        lam: jax.Array) -> jax.Array:
    """q = Σᵢ B̃ᵢᵀ-scatter( F̃ᵢ · gather(λ) )   (paper eq. 12)."""
    return local_dual_apply(
        lambda p: jnp.einsum("sab,sb->sa", F, p), lambda_ids, n_lambda, lam)


def _tri_solve(L, b, transpose):
    return jax.lax.linalg.triangular_solve(
        L, b[..., None], left_side=True, lower=True, transpose_a=transpose
    )[..., 0]


def solve_with_factor(L, b: jax.Array) -> jax.Array:
    """Apply (L Lᵀ)⁻¹ to a subdomain-stacked (S, n) right-hand side.

    The one forward/backward triangular-solve pair every consumer of the
    factor shares (implicit dual operator, dual RHS, solution recovery).
    ``L`` is either a dense (S, n, n) stack or a packed
    :class:`~repro.sparse.packed.PackedBlocks` stack — same semantics.
    """
    if isinstance(L, PackedBlocks):
        fwd = jax.vmap(packed_tri_solve, in_axes=(0, 0, None))
        return fwd(L, fwd(L, b, False), True)
    t = jax.vmap(_tri_solve, in_axes=(0, 0, None))(L, b, False)
    return jax.vmap(_tri_solve, in_axes=(0, 0, None))(L, t, True)


def apply_stiffness(K, v: jax.Array) -> jax.Array:
    """Batched ``Kᵢ vᵢ`` for a stiffness stack stored dense or packed
    (packed = the symmetric lower block triangle in fill-mask layout)."""
    if isinstance(K, PackedBlocks):
        return jax.vmap(packed_symm_matvec)(K, v)
    return jnp.einsum("snk,sk->sn", K, v)


def implicit_dual_apply(L, Btp: jax.Array, lambda_ids: jax.Array,
                        n_lambda: int, lam: jax.Array) -> jax.Array:
    """q = Σᵢ scatter( B̃ᵢ L⁻ᵀL⁻¹ B̃ᵢᵀ gather(λ) )  (paper eq. 11)."""
    p_loc = gather_local(lam, lambda_ids)
    v = jnp.einsum("snm,sm->sn", Btp, p_loc)
    t = solve_with_factor(L, v)
    q_loc = jnp.einsum("snm,sn->sm", Btp, t)
    return scatter_dual(q_loc, lambda_ids, n_lambda)


def lumped_preconditioner(K, Bt: jax.Array, lambda_ids: jax.Array,
                          n_lambda: int, w: jax.Array) -> jax.Array:
    """Lumped FETI preconditioner: M⁻¹ ≈ Σᵢ B̃ᵢ Kᵢ B̃ᵢᵀ.

    The cheap special case of the Dirichlet sandwich below with the FULL
    stiffness K standing in for the boundary Schur complement S_b (lumping
    the interior contribution instead of eliminating it — zero extra
    preprocessing, weaker spectral equivalence; docs/preconditioners.md).

    ``K`` is the unregularized stiffness stack — dense, or packed in the
    factor's block layout (the form :func:`repro.feti.assembly.
    preprocess_cluster` stores: no dense (S, n, n) K survives preprocessing).
    ``Bt`` must share K's row order (the factor order when K is packed).
    """

    def apply_local(p):
        v = jnp.einsum("snm,sm->sn", Bt, p)
        v = apply_stiffness(K, v)
        return jnp.einsum("snm,sn->sm", Bt, v)

    return local_dual_apply(apply_local, lambda_ids, n_lambda, w)


def dirichlet_preconditioner(Sb: jax.Array, Btb: jax.Array,
                             lambda_ids: jax.Array, n_lambda: int,
                             w: jax.Array) -> jax.Array:
    """Dirichlet FETI preconditioner: M⁻¹ = Σᵢ B̃ᵢ S_b,i B̃ᵢᵀ with the
    *primal* boundary Schur complement S_b = K_bb − K_bi K_ii⁻¹ K_ib
    assembled per subdomain by :mod:`repro.feti.dirichlet`.

    ``Sb`` is the dense (S, n_b, n_b) stack; ``Btb`` is the boundary-row
    slice of B̃ᵀ, (S, n_b, m_max) — B̃ᵀ has no interior rows by
    construction of the split, so the restriction loses nothing. The apply
    is gather → restrict to boundary → dense GEMV against S_b → expand →
    scatter, the preconditioner mirror of :func:`explicit_dual_apply`.
    """

    def apply_local(p):
        v = jnp.einsum("sbm,sm->sb", Btb, p)
        v = jnp.einsum("sab,sb->sa", Sb, v)
        return jnp.einsum("sbm,sb->sm", Btb, v)

    return local_dual_apply(apply_local, lambda_ids, n_lambda, w)


def dual_rhs(L, Btp: jax.Array, fp: jax.Array,
             lambda_ids: jax.Array, n_lambda: int, c: jax.Array) -> jax.Array:
    """d = B K⁺ f − c (paper §2.1)."""
    t = solve_with_factor(L, fp)
    q_loc = jnp.einsum("snm,sn->sm", Btp, t)
    return scatter_dual(q_loc, lambda_ids, n_lambda) - c


# --------------------------------------------------------------------------
# multi-RHS column-stacked variants (ISSUE 6)
# --------------------------------------------------------------------------
#
# Same operators on (.., n_rhs) column stacks: multiplier stacks are
# (n_lambda, n_rhs), subdomain-local stacks (S, n, n_rhs). Kept as separate
# functions (not a rank-polymorphic rewrite of the single-RHS ones) so the
# single-column programs — whose iteration counts several tests pin — stay
# byte-identical; gather/scatter are shared because indexing is naturally
# rank-generic. The per-subdomain GEMV of the single-RHS path widens to a
# GEMM, which is exactly the amortization story: the SC / factor /
# preconditioner stacks are read from memory once per *block* application
# and reused across all columns.

def local_dual_apply_many(apply_local, lambda_ids: jax.Array, n_lambda: int,
                          Lam: jax.Array) -> jax.Array:
    """Gather → local apply → scatter for an (n_lambda, n_rhs) stack.

    ``apply_local`` maps (S, m_max, n_rhs) gathered column stacks to
    (S, m_max, n_rhs) results.
    """
    return scatter_dual(apply_local(gather_local(Lam, lambda_ids)),
                        lambda_ids, n_lambda)


def explicit_dual_apply_many(F: jax.Array, lambda_ids: jax.Array,
                             n_lambda: int, Lam: jax.Array) -> jax.Array:
    """Eq. 12 on a column stack: one (m×m)·(m×r) GEMM per subdomain."""
    return local_dual_apply_many(
        lambda p: jnp.einsum("sab,sbr->sar", F, p), lambda_ids, n_lambda, Lam)


def solve_with_factor_many(L, B: jax.Array) -> jax.Array:
    """(L Lᵀ)⁻¹ applied to a subdomain-stacked (S, n, n_rhs) column block.

    Dense factors use the batched multi-RHS triangular solve directly;
    packed factors vmap :func:`~repro.sparse.packed.packed_tri_solve` over
    the trailing column axis (the packed kernel is single-RHS by design —
    its block loop is structure-driven, not RHS-driven).
    """
    if isinstance(L, PackedBlocks):
        cols = jax.vmap(packed_tri_solve, in_axes=(None, 1, None), out_axes=1)
        fwd = jax.vmap(cols, in_axes=(0, 0, None))
        return fwd(L, fwd(L, B, False), True)

    def tri(L_, B_, transpose):
        return jax.lax.linalg.triangular_solve(
            L_, B_, left_side=True, lower=True, transpose_a=transpose)

    t = jax.vmap(tri, in_axes=(0, 0, None))(L, B, False)
    return jax.vmap(tri, in_axes=(0, 0, None))(L, t, True)


def apply_stiffness_many(K, V: jax.Array) -> jax.Array:
    """Batched ``Kᵢ Vᵢ`` for an (S, n, n_rhs) column block (dense/packed)."""
    if isinstance(K, PackedBlocks):
        cols = jax.vmap(packed_symm_matvec, in_axes=(None, 1), out_axes=1)
        return jax.vmap(cols)(K, V)
    return jnp.einsum("snk,skr->snr", K, V)


def implicit_dual_apply_many(L, Btp: jax.Array, lambda_ids: jax.Array,
                             n_lambda: int, Lam: jax.Array) -> jax.Array:
    """Eq. 11 on a column stack: SPMM + multi-RHS TRSM + SPMM."""
    p_loc = gather_local(Lam, lambda_ids)  # (S, m_max, n_rhs)
    v = jnp.einsum("snm,smr->snr", Btp, p_loc)
    t = solve_with_factor_many(L, v)
    q_loc = jnp.einsum("snm,snr->smr", Btp, t)
    return scatter_dual(q_loc, lambda_ids, n_lambda)


def lumped_preconditioner_many(K, Bt: jax.Array, lambda_ids: jax.Array,
                               n_lambda: int, W: jax.Array) -> jax.Array:
    """Lumped preconditioner on an (n_lambda, n_rhs) residual stack."""

    def apply_local(p):
        v = jnp.einsum("snm,smr->snr", Bt, p)
        v = apply_stiffness_many(K, v)
        return jnp.einsum("snm,snr->smr", Bt, v)

    return local_dual_apply_many(apply_local, lambda_ids, n_lambda, W)


def dirichlet_preconditioner_many(Sb: jax.Array, Btb: jax.Array,
                                  lambda_ids: jax.Array, n_lambda: int,
                                  W: jax.Array) -> jax.Array:
    """Dirichlet preconditioner on an (n_lambda, n_rhs) residual stack."""

    def apply_local(p):
        v = jnp.einsum("sbm,smr->sbr", Btb, p)
        v = jnp.einsum("sab,sbr->sar", Sb, v)
        return jnp.einsum("sbm,sbr->smr", Btb, v)

    return local_dual_apply_many(apply_local, lambda_ids, n_lambda, W)


def dual_rhs_many(L, Btp: jax.Array, Fp: jax.Array, lambda_ids: jax.Array,
                  n_lambda: int, c: jax.Array) -> jax.Array:
    """D = B K⁺ F − c1ᵀ for an (S, n, n_rhs) load-case stack ``Fp``
    (factor row order); ``c`` broadcasts over the column axis."""
    t = solve_with_factor_many(L, Fp)
    q_loc = jnp.einsum("snm,snr->smr", Btp, t)
    return scatter_dual(q_loc, lambda_ids, n_lambda) - c[:, None]
