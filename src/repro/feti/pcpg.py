"""Preconditioned Conjugate Projected Gradient (paper §2.1, [10]).

Jittable lax.while_loop implementation; the dual operator F, the projector
P and the preconditioner M⁻¹ are injected as closures, so the same loop
serves implicit/explicit operators, single-host batched or mesh-sharded
deployments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["PCPGResult", "pcpg"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PCPGResult:
    lam: jax.Array
    iterations: jax.Array  # int32 scalar
    residual: jax.Array  # final ||P r||
    converged: jax.Array  # bool scalar


def _identity(x: jax.Array) -> jax.Array:
    return x


def pcpg(
    apply_F: Callable[[jax.Array], jax.Array],
    project: Callable[[jax.Array], jax.Array],
    d: jax.Array,
    lam0: jax.Array,
    precondition: Optional[Callable[[jax.Array], jax.Array]] = None,
    tol: float = 1e-9,
    max_iter: int = 500,
    mesh=None,
) -> PCPGResult:
    """Solve P F λ = P d on the affine space λ⁰ + Ker(Gᵀ).

    Iterates:  w = P r;  z = P M⁻¹ w;  standard CG update with (z·w) inner
    products. Without a preconditioner z = w (M = I).

    ``mesh`` (optional, the subdomain-sharded deployment of
    :mod:`repro.feti.sharded`) pins the CG carries to replicated layout so
    GSPMD never round-trips the dual vectors through a sharded
    representation between the shard_map'd operator applications; with
    ``mesh=None`` the loop is exactly the single-device program.
    """
    if precondition is None:
        precondition = _identity
    if mesh is None:
        constrain = _identity
    else:
        from jax.sharding import NamedSharding, PartitionSpec

        replicated = NamedSharding(mesh, PartitionSpec())

        def constrain(x):
            return jax.lax.with_sharding_constraint(x, replicated)

    r0 = d - apply_F(lam0)
    w0 = project(r0)
    z0 = project(precondition(w0))
    zeta0 = jnp.vdot(z0, w0)
    norm_w0 = jnp.linalg.norm(w0)
    atol = tol * jnp.maximum(norm_w0, 1e-30)

    def cond(carry):
        lam, r, p, zeta, w_norm, k = carry
        return jnp.logical_and(k < max_iter, w_norm > atol)

    def body(carry):
        lam, r, p, zeta, _, k = carry
        Fp = apply_F(p)
        gamma = zeta / jnp.vdot(p, Fp)
        lam = constrain(lam + gamma * p)
        r = constrain(r - gamma * Fp)
        w = project(r)
        z = project(precondition(w))
        zeta_new = jnp.vdot(z, w)
        beta = zeta_new / zeta
        p = constrain(z + beta * p)
        return lam, r, p, zeta_new, jnp.linalg.norm(w), k + 1

    init = (lam0, r0, z0, zeta0, norm_w0, jnp.asarray(0, jnp.int32))
    lam, r, p, zeta, w_norm, k = jax.lax.while_loop(cond, body, init)
    return PCPGResult(
        lam=lam, iterations=k, residual=w_norm, converged=w_norm <= atol
    )
