"""Preconditioned Conjugate Projected Gradient (paper §2.1, [10]).

Jittable lax.while_loop implementation; the dual operator F, the projector
P and the preconditioner M⁻¹ are injected as closures, so the same loop
serves implicit/explicit operators, single-host batched or mesh-sharded
deployments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["PCPGResult", "PCPGManyResult", "pcpg", "pcpg_many"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PCPGResult:
    lam: jax.Array
    iterations: jax.Array  # int32 scalar
    residual: jax.Array  # final ||P r||
    converged: jax.Array  # bool scalar


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PCPGManyResult:
    lam: jax.Array  # (n_lambda, n_rhs) multiplier stack
    iterations: jax.Array  # (n_rhs,) int32 per-column iteration counts
    residual: jax.Array  # (n_rhs,) final per-column ||P r||
    converged: jax.Array  # (n_rhs,) bool
    block_iterations: jax.Array  # int32 scalar: loop trips executed


def _identity(x: jax.Array) -> jax.Array:
    return x


def pcpg(
    apply_F: Callable[[jax.Array], jax.Array],
    project: Callable[[jax.Array], jax.Array],
    d: jax.Array,
    lam0: jax.Array,
    precondition: Optional[Callable[[jax.Array], jax.Array]] = None,
    tol: float = 1e-9,
    max_iter: int = 500,
    mesh=None,
) -> PCPGResult:
    """Solve P F λ = P d on the affine space λ⁰ + Ker(Gᵀ).

    Iterates:  w = P r;  z = P M⁻¹ w;  standard CG update with (z·w) inner
    products. Without a preconditioner z = w (M = I).

    ``mesh`` (optional, the subdomain-sharded deployment of
    :mod:`repro.feti.sharded`) pins the CG carries to replicated layout so
    GSPMD never round-trips the dual vectors through a sharded
    representation between the shard_map'd operator applications; with
    ``mesh=None`` the loop is exactly the single-device program.
    """
    if precondition is None:
        precondition = _identity
    if mesh is None:
        constrain = _identity
    else:
        from jax.sharding import NamedSharding, PartitionSpec

        replicated = NamedSharding(mesh, PartitionSpec())

        def constrain(x):
            return jax.lax.with_sharding_constraint(x, replicated)

    r0 = d - apply_F(lam0)
    w0 = project(r0)
    z0 = project(precondition(w0))
    zeta0 = jnp.vdot(z0, w0)
    norm_w0 = jnp.linalg.norm(w0)
    atol = tol * jnp.maximum(norm_w0, 1e-30)

    def cond(carry):
        lam, r, p, zeta, w_norm, k = carry
        return jnp.logical_and(k < max_iter, w_norm > atol)

    def body(carry):
        lam, r, p, zeta, _, k = carry
        Fp = apply_F(p)
        gamma = zeta / jnp.vdot(p, Fp)
        lam = constrain(lam + gamma * p)
        r = constrain(r - gamma * Fp)
        w = project(r)
        z = project(precondition(w))
        zeta_new = jnp.vdot(z, w)
        beta = zeta_new / zeta
        p = constrain(z + beta * p)
        return lam, r, p, zeta_new, jnp.linalg.norm(w), k + 1

    init = (lam0, r0, z0, zeta0, norm_w0, jnp.asarray(0, jnp.int32))
    lam, r, p, zeta, w_norm, k = jax.lax.while_loop(cond, body, init)
    return PCPGResult(
        lam=lam, iterations=k, residual=w_norm, converged=w_norm <= atol
    )


def pcpg_many(
    apply_F: Callable[[jax.Array], jax.Array],
    project: Callable[[jax.Array], jax.Array],
    D: jax.Array,
    Lam0: jax.Array,
    precondition: Optional[Callable[[jax.Array], jax.Array]] = None,
    tol: float = 1e-9,
    max_iter: int = 500,
    mesh=None,
) -> PCPGManyResult:
    """Block-batched PCPG over an (n_lambda, n_rhs) multiplier stack with
    per-column stopping.

    Each column j runs the SAME iteration as :func:`pcpg` on its own
    (d_j, λ⁰_j) — inner products, step lengths and stopping tests are all
    per-column (reductions over the λ axis only), so the trajectory of a
    column is independent of what its neighbours carry. The win over
    ``vmap(pcpg)`` is shared operator traffic: ``apply_F``/``project``/
    ``precondition`` see the whole (n_lambda, n_rhs) stack at once, so the
    explicit SC stack (and the preconditioner stacks) stream from memory
    once per *block* iteration instead of once per column — the multi-RHS
    amortization the paper's explicit assembly exists for.

    Per-column stopping freezes converged columns in place: their λ/r/p
    carries stop updating (``jnp.where`` masks with safe denominators, so
    no NaNs leak from frozen columns), their recorded residual/iteration
    count stays at the converged value, and the loop exits when every
    column is frozen or ``max_iter`` block iterations have run. A frozen
    column still rides through the operator applications (its flops are
    spent regardless — the block shape is static), which keeps the loop a
    single ``lax.while_loop`` with one compiled program per (n_lambda,
    n_rhs) shape; see docs/multirhs.md for the tradeoff discussion.

    ``mesh`` has the same meaning as in :func:`pcpg`: carries pinned to
    replicated layout between the shard_map'd operator applications.
    """
    if precondition is None:
        precondition = _identity
    if mesh is None:
        constrain = _identity
    else:
        from jax.sharding import NamedSharding, PartitionSpec

        replicated = NamedSharding(mesh, PartitionSpec())

        def constrain(x):
            return jax.lax.with_sharding_constraint(x, replicated)

    def col_dot(a, b):
        return jnp.sum(a * b, axis=0)  # (n_rhs,) per-column inner products

    def col_norm(a):
        return jnp.sqrt(jnp.sum(a * a, axis=0))

    R0 = D - apply_F(Lam0)
    W0 = project(R0)
    Z0 = project(precondition(W0))
    zeta0 = col_dot(Z0, W0)
    norm_w0 = col_norm(W0)
    atol = tol * jnp.maximum(norm_w0, 1e-30)  # (n_rhs,)
    active0 = norm_w0 > atol  # already-converged (e.g. zero-load padding)
    #                           columns never enter the loop: 0 iterations

    def cond(carry):
        _, _, _, _, _, active, _, k = carry
        return jnp.logical_and(k < max_iter, jnp.any(active))

    def body(carry):
        Lam, R, Pm, zeta, w_norm, active, iters, k = carry
        FP = apply_F(Pm)
        pFp = col_dot(Pm, FP)
        gamma = jnp.where(active, zeta / jnp.where(active, pFp, 1.0), 0.0)
        Lam = constrain(Lam + gamma * Pm)
        R = constrain(R - gamma * FP)
        # frozen columns have unchanged R, hence unchanged W/Z — cheap to
        # recompute (block ops), and their w_norm/zeta stay at the frozen
        # values without extra masking
        W = project(R)
        Z = project(precondition(W))
        zeta_new = col_dot(Z, W)
        beta = jnp.where(active, zeta_new / jnp.where(active, zeta, 1.0), 0.0)
        Pm = constrain(jnp.where(active, Z + beta * Pm, Pm))
        zeta = jnp.where(active, zeta_new, zeta)
        w_norm = jnp.where(active, col_norm(W), w_norm)
        iters = iters + active.astype(jnp.int32)
        active = jnp.logical_and(active, w_norm > atol)
        return Lam, R, Pm, zeta, w_norm, active, iters, k + 1

    n_rhs = D.shape[1]
    init = (
        Lam0, R0, Z0, zeta0, norm_w0, active0,
        jnp.zeros((n_rhs,), jnp.int32), jnp.asarray(0, jnp.int32),
    )
    Lam, R, Pm, zeta, w_norm, active, iters, k = jax.lax.while_loop(
        cond, body, init)
    return PCPGManyResult(
        lam=Lam, iterations=iters, residual=w_norm,
        converged=w_norm <= atol, block_iterations=k,
    )
