"""End-to-end FETI solver (paper §2 + §5).

Stages exactly as the paper defines them:
  initialization —  symbolic factorization & persistent structures
                    (inside :func:`repro.feti.assembly.preprocess_cluster`),
  preprocessing  —  numerical factorization + explicit SC assembly,
  solution       —  PCPG iterations applying the dual operator.

``FetiSolver(mode=...)`` selects the implicit (eq. 11) or explicit (eq. 12)
dual operator; ``amortization_report`` computes the iteration count at which
the explicit approach pays off — the paper's central figure of merit
(Fig. 10: ≈10 iterations with the sparsity-utilizing assembly).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SchurAssemblyConfig, assembly_flops
from repro.feti.assembly import ClusterState, preprocess_cluster
from repro.feti.config import FetiConfig, _coerce_config
from repro.feti.operator import (
    dirichlet_preconditioner,
    dirichlet_preconditioner_many,
    dual_rhs,
    dual_rhs_many,
    explicit_dual_apply,
    explicit_dual_apply_many,
    gather_local,
    implicit_dual_apply,
    implicit_dual_apply_many,
    lumped_preconditioner,
    lumped_preconditioner_many,
    solve_with_factor,
    solve_with_factor_many,
)
from repro.feti.pcpg import PCPGManyResult, PCPGResult, pcpg, pcpg_many
from repro.feti.projector import build_coarse_problem, coarse_e, coarse_e_many
from repro.fem.decomposition import FetiProblem

__all__ = ["FetiSolver", "FetiSolution", "FetiManySolution",
           "PRECONDITIONERS", "solve_many"]

PRECONDITIONERS = ("lumped", "dirichlet", "none")


@dataclasses.dataclass
class FetiSolution:
    u: np.ndarray  # (S, n) subdomain solutions, original DOF order
    u_global: np.ndarray  # (n_global_dofs,) averaged onto the global mesh
    lam: np.ndarray
    alpha: np.ndarray  # (S, k) kernel coefficients per subdomain
    iterations: int
    residual: float
    converged: bool
    timings: dict


@dataclasses.dataclass
class FetiManySolution:
    """A batch of load-case solutions from :meth:`FetiSolver.solve_many`.

    All arrays carry the load-case index first; padding columns (when
    ``rhs_unit`` rounded the batch up) are already stripped."""

    u: np.ndarray  # (n_rhs, S, n) subdomain solutions, original DOF order
    u_global: np.ndarray  # (n_rhs, n_global_dofs)
    lam: np.ndarray  # (n_rhs, n_lambda)
    alpha: np.ndarray  # (n_rhs, S, k)
    iterations: np.ndarray  # (n_rhs,) per-column PCPG iteration counts
    residuals: np.ndarray  # (n_rhs,) per-column final ||P r||
    converged: np.ndarray  # (n_rhs,) bool
    block_iterations: int  # block-PCPG loop trips (= max of iterations)
    n_rhs: int  # requested load cases
    n_rhs_padded: int  # columns actually solved (rhs_unit padding)
    timings: dict


@dataclasses.dataclass
class _SolutionOps:
    """Load-independent solution-phase machinery, built once per cluster
    state and reused across :meth:`FetiSolver.solve` /
    :meth:`FetiSolver.solve_many` calls — the server-style reuse pattern:
    everything here depends only on the preprocessed cluster, so streaming
    a new load case costs one RHS build plus PCPG iterations."""

    coarse: object  # CoarseProblem / ShardedCoarseProblem
    apply_F: Callable  # (n_lambda,) -> (n_lambda,)
    apply_F_many: Callable  # (n_lambda, r) -> (n_lambda, r)
    precond: Optional[Callable]
    precond_many: Optional[Callable]
    dual_rhs_vec: Callable  # fp (S, n) -> d (n_lambda,)
    dual_rhs_cols: Callable  # Fp (S, n, r) -> D (n_lambda, r)
    coarse_e_vec: Callable  # f (S, n) -> e (S·k,)
    coarse_e_cols: Callable  # F (S, n, r) -> E (S·k, r)


class FetiSolver:
    """Drives preprocess + PCPG for one cluster (batched subdomains)."""

    def __init__(self, problem: FetiProblem, config=None, **deprecated):
        """``config`` is a :class:`~repro.feti.config.FetiConfig` or one of
        its shorthand forms: ``None`` (defaults), a bare
        ``SchurAssemblyConfig``, or the string ``"auto"`` (the stage graph
        plans every assembly stage jointly during :meth:`preprocess`;
        ``self.cfg``/``self.plan`` carry the resolved dual-stage config and
        its cost report afterwards, ``self.state.graph_plan`` the joint
        result). The pre-FetiConfig keyword arguments (``cfg=``, ``mode=``,
        ``preconditioner=``, ``ordering=``, ``dtype=``, ``measure=``,
        ``plan_cache=``, ``mesh=``, ``storage=``) still work via
        ``**deprecated`` but emit a ``DeprecationWarning`` — see README
        §Migrating to FetiConfig.

        ``FetiConfig.mesh`` (a ``("data",)`` device mesh, see
        :func:`repro.launch.mesh.make_feti_mesh`) shards the subdomain
        axis over devices: preprocessing partitions per-device and the
        PCPG operators run under shard_map with psum exchange
        (:mod:`repro.feti.sharded`). ``mesh=None`` keeps the single-device
        batched behavior bit-for-bit."""
        fc = _coerce_config(config, deprecated, "FetiSolver")
        self.problem = problem
        self.config = fc
        # resolved views, kept as public attributes for existing callers;
        # cfg/plan are overwritten with the planner's choice on preprocess
        self.cfg = fc.schur if fc.schur is not None else SchurAssemblyConfig()
        self.plan = None
        self.mode = fc.mode
        self.preconditioner = fc.preconditioner
        self.ordering = fc.ordering
        self.dtype = fc.dtype
        self.measure = fc.measure
        self.plan_cache = fc.plan_cache
        self.mesh = fc.mesh
        self.storage = fc.storage
        self.state: Optional[ClusterState] = None
        self.timings: dict = {}
        self._ops: Optional[_SolutionOps] = None
        self._runs: dict = {}  # (tol, max_iter) -> jitted pcpg
        self._many_runs: dict = {}  # (tol, max_iter) -> jitted pcpg_many

    # ---- preprocessing (paper §2.2) ----
    def preprocess(self) -> ClusterState:
        t0 = time.perf_counter()
        self.state = preprocess_cluster(self.problem, self.config)
        jax.block_until_ready(self.state.L)
        if self.state.F is not None:
            jax.block_until_ready(self.state.F)
        if self.state.Sb is not None:
            jax.block_until_ready(self.state.Sb)
        self.cfg = self.state.cfg  # resolved when "auto" was passed
        self.plan = self.state.plan
        self._ops = None  # operators close over state arrays
        self._runs = {}
        self._many_runs = {}
        self.timings["preprocess_s"] = time.perf_counter() - t0
        return self.state

    # ---- solution-phase machinery, load-independent ----
    def _solution_ops(self) -> _SolutionOps:
        """Coarse problem + operator closures, built once per state and
        cached: the pieces of the solution phase that do NOT depend on the
        load, so streamed load cases reuse them (and their jit caches)."""
        if self._ops is not None:
            return self._ops
        st = self.state
        prob = self.problem
        nl = prob.n_lambda
        c = jnp.asarray(prob.c, dtype=self.dtype)
        Bt_host = np.stack([sd.Bt for sd in prob.subdomains])

        if st.mesh is None:
            Bt_orig = jnp.asarray(Bt_host, dtype=self.dtype)
            coarse = build_coarse_problem(
                Bt_orig, st.f, st.R, st.lambda_ids, nl
            )
            if self.mode == "explicit":
                apply_F = partial(explicit_dual_apply, st.F, st.lambda_ids,
                                  nl)
                apply_F_many = partial(explicit_dual_apply_many, st.F,
                                       st.lambda_ids, nl)
            else:
                apply_F = partial(implicit_dual_apply, st.L, st.Btp,
                                  st.lambda_ids, nl)
                apply_F_many = partial(implicit_dual_apply_many, st.L,
                                       st.Btp, st.lambda_ids, nl)
            # K is packed in factor row order, so it pairs with Btp (the
            # product B̃ K B̃ᵀ is invariant to the shared row permutation)
            precond_args = (st.K, st.Btp, st.lambda_ids, nl)
            precond_fn = lumped_preconditioner
            precond_fn_many = lumped_preconditioner_many
            dirichlet_args = (st.Sb, st.Btb, st.lambda_ids, nl)
            dirichlet_fn = dirichlet_preconditioner
            dirichlet_fn_many = dirichlet_preconditioner_many
            dual_rhs_vec = lambda fp: dual_rhs(  # noqa: E731
                st.L, st.Btp, fp, st.lambda_ids, nl, c)
            dual_rhs_cols = lambda Fp: dual_rhs_many(  # noqa: E731
                st.L, st.Btp, Fp, st.lambda_ids, nl, c)
            coarse_e_vec = lambda f: coarse_e(f, st.R)  # noqa: E731
            coarse_e_cols = lambda F: coarse_e_many(F, st.R)  # noqa: E731
        else:
            from repro.feti import sharded as shlib

            # match the state's relabeled multiplier columns, pad the
            # dummy subdomains (zero gluing), and shard like the stacks
            Bt_rel = shlib.relabel_columns(Bt_host, np.asarray(st.col_perm))
            Bt_orig = shlib.shard_stack(
                st.mesh, np.asarray(shlib.pad_stack(Bt_rel, st.S),
                                    dtype=self.dtype))
            coarse = shlib.build_coarse_problem(
                st.mesh, Bt_orig, st.f, st.R, st.lambda_ids, nl,
                S_real=st.S_real,
            )
            if self.mode == "explicit":
                apply_F = partial(shlib.explicit_dual_apply, st.mesh, st.F,
                                  st.lambda_ids, nl)
                apply_F_many = partial(shlib.explicit_dual_apply_many,
                                       st.mesh, st.F, st.lambda_ids, nl)
            else:
                apply_F = partial(shlib.implicit_dual_apply, st.mesh, st.L,
                                  st.Btp, st.lambda_ids, nl)
                apply_F_many = partial(shlib.implicit_dual_apply_many,
                                       st.mesh, st.L, st.Btp,
                                       st.lambda_ids, nl)
            precond_args = (st.mesh, st.K, st.Btp, st.lambda_ids, nl)
            precond_fn = shlib.lumped_preconditioner
            precond_fn_many = shlib.lumped_preconditioner_many
            dirichlet_args = (st.mesh, st.Sb, st.Btb, st.lambda_ids, nl)
            dirichlet_fn = shlib.dirichlet_preconditioner
            dirichlet_fn_many = shlib.dirichlet_preconditioner_many
            dual_rhs_vec = lambda fp: shlib.dual_rhs(  # noqa: E731
                st.mesh, st.L, st.Btp, fp, st.lambda_ids, nl, c)
            dual_rhs_cols = lambda Fp: shlib.dual_rhs_many(  # noqa: E731
                st.mesh, st.L, st.Btp, Fp, st.lambda_ids, nl, c)
            coarse_e_vec = lambda f: shlib.coarse_e(  # noqa: E731
                st.mesh, f, st.R)
            coarse_e_cols = lambda F: shlib.coarse_e_many(  # noqa: E731
                st.mesh, F, st.R)

        if self.preconditioner == "lumped":
            precond = partial(precond_fn, *precond_args)
            precond_many = partial(precond_fn_many, *precond_args)
        elif self.preconditioner == "dirichlet":
            if st.Sb is None:
                raise ValueError(
                    "state was preprocessed without the dirichlet stage; "
                    "construct the solver with preconditioner='dirichlet' "
                    "before preprocess()")
            precond = partial(dirichlet_fn, *dirichlet_args)
            precond_many = partial(dirichlet_fn_many, *dirichlet_args)
        elif self.preconditioner == "none":
            precond = None
            precond_many = None
        else:
            raise ValueError(f"unknown preconditioner {self.preconditioner!r}")

        self._ops = _SolutionOps(
            coarse=coarse, apply_F=apply_F, apply_F_many=apply_F_many,
            precond=precond, precond_many=precond_many,
            dual_rhs_vec=dual_rhs_vec, dual_rhs_cols=dual_rhs_cols,
            coarse_e_vec=coarse_e_vec, coarse_e_cols=coarse_e_cols,
        )
        return self._ops

    def _load_stacks(self, loads: np.ndarray):
        """Host (S_real, n, ...) load stack -> device (f, fp) arrays in
        original and factor row order, padded + sharded when meshed."""
        st = self.state
        f_host = np.asarray(loads, dtype=self.dtype)
        fp_host = f_host[:, np.asarray(st.node_perm)]
        if st.mesh is None:
            return jnp.asarray(f_host), jnp.asarray(fp_host)
        from repro.feti import sharded as shlib

        return (
            shlib.shard_stack(st.mesh, shlib.pad_stack(f_host, st.S)),
            shlib.shard_stack(st.mesh, shlib.pad_stack(fp_host, st.S)),
        )

    def _recover_u(self, up, alpha_flat, n_cols: Optional[int]):
        """Shared recovery tail: factor-order K⁺(f − Bᵀλ) + kernel
        correction, back-permuted to original DOF order and averaged onto
        the global mesh. ``n_cols=None`` recovers one solution ((S, n) /
        (n_global,)); an int recovers that many stacked columns with the
        load-case axis leading."""
        st = self.state
        prob = self.problem
        k = st.R.shape[2]
        inv_perm = np.argsort(st.node_perm)
        up_h = np.asarray(up)[: st.S_real]
        R_h = np.asarray(st.R)[: st.S_real]
        if n_cols is None:
            alpha = np.asarray(alpha_flat).reshape(st.S, k)[: st.S_real]
            u = up_h[:, inv_perm] + np.einsum("snk,sk->sn", R_h, alpha)
        else:
            alpha = np.asarray(alpha_flat).reshape(
                st.S, k, n_cols)[: st.S_real]
            u = (up_h[:, inv_perm]
                 + np.einsum("snk,skr->snr", R_h, alpha))
            u = np.moveaxis(u, -1, 0)  # (n_rhs, S, n)
            alpha = np.moveaxis(alpha, -1, 0)  # (n_rhs, S, k)

        # average duplicated interface copies onto the global mesh (DOFs)
        nn = prob.n_global_dofs
        lead = () if n_cols is None else (n_cols,)
        acc = np.zeros(lead + (nn,))
        cnt = np.zeros(nn)
        for i, sd in enumerate(prob.subdomains):
            np.add.at(acc, (..., sd.dof_gids), u[..., i, :])
            np.add.at(cnt, sd.dof_gids, 1.0)
        u_global = acc / np.maximum(cnt, 1.0)
        return u, alpha, u_global

    # ---- solution (paper §2.2) ----
    def solve(self, tol: float = 1e-9, max_iter: int = 2000,
              loads: Optional[np.ndarray] = None) -> FetiSolution:
        """One PCPG solve. ``loads`` (optional, host (S_real, n) stack in
        original DOF order) overrides the problem's own load vectors —
        the single-case form of the :meth:`solve_many` streaming path."""
        if self.state is None:
            self.preprocess()
        st = self.state
        ops = self._solution_ops()
        coarse = ops.coarse

        if loads is None:
            fp_dev = st.fp
            lam0 = coarse.lambda0()
        else:
            f_dev, fp_dev = self._load_stacks(loads)
            lam0 = coarse.lambda0(ops.coarse_e_vec(f_dev))
        d = ops.dual_rhs_vec(fp_dev)

        t0 = time.perf_counter()
        res: PCPGResult = self._run(tol, max_iter)(d, lam0)
        jax.block_until_ready(res.lam)
        self.timings["solve_s"] = time.perf_counter() - t0

        # ---- recover α and u (paper eqs. 5, 7) ----
        Flam = ops.apply_F(res.lam)
        alpha_flat = coarse.alpha(Flam - d)  # (S·k,), subdomain-major
        lam_loc = gather_local(res.lam, st.lambda_ids)
        rhs = fp_dev - jnp.einsum("snm,sm->sn", st.Btp, lam_loc)
        up = solve_with_factor(st.L, rhs)
        # back to original DOF order + kernel (rigid-body) correction
        # u_i = K⁺(f − Bᵀλ)_i + R_i α_i; drop any inert mesh-padding
        # subdomains (S_real == S unsharded)
        u, alpha, u_global = self._recover_u(up, alpha_flat, None)

        return FetiSolution(
            u=u,
            u_global=u_global,
            lam=np.asarray(res.lam),
            alpha=alpha,
            iterations=int(res.iterations),
            residual=float(res.residual),
            converged=bool(res.converged),
            timings=dict(self.timings),
        )

    def _run(self, tol: float, max_iter: int):
        """Jitted single-RHS PCPG runner, cached per (tol, max_iter): a
        stream of single load cases (``solve(loads=...)`` or 1-column
        :meth:`solve_many` batches) traces and compiles exactly once per
        tolerance instead of once per call. The cached wrapper runs the
        same compiled program a fresh ``jax.jit`` would, so results are
        bit-identical to the uncached form."""
        key = (float(tol), int(max_iter))
        run = self._runs.get(key)
        if run is None:
            ops = self._solution_ops()
            run = jax.jit(
                lambda d_, lam0_: pcpg(
                    ops.apply_F, ops.coarse.project, d_, lam0_,
                    precondition=ops.precond, tol=tol, max_iter=max_iter,
                    mesh=self.state.mesh,
                )
            )
            self._runs[key] = run
        return run

    def _many_run(self, tol: float, max_iter: int):
        """Jitted block-PCPG runner, cached per (tol, max_iter) so a
        stream of equally-shaped batches compiles exactly once (jax.jit
        handles distinct (n_lambda, n_rhs) shapes within one runner)."""
        key = (float(tol), int(max_iter))
        run = self._many_runs.get(key)
        if run is None:
            ops = self._solution_ops()
            run = jax.jit(
                lambda D_, Lam0_: pcpg_many(
                    ops.apply_F_many, ops.coarse.project, D_, Lam0_,
                    precondition=ops.precond_many, tol=tol,
                    max_iter=max_iter, mesh=self.state.mesh,
                )
            )
            self._many_runs[key] = run
        return run

    def solve_many(self, loads, tol: float = 1e-9, max_iter: int = 2000,
                   rhs_unit: int = 1) -> FetiManySolution:
        """Solve a batch of load cases against the cached cluster state.

        This is the server-style entry point the amortization story asks
        for: :meth:`preprocess` is paid once (factorization, explicit SC
        assembly, autotuned plans, Dirichlet S_b), then an arbitrary
        sequence of ``solve_many`` calls streams load-case batches through
        one block-PCPG (:func:`repro.feti.pcpg.pcpg_many`) whose operator
        applications touch the cached stacks once per block iteration for
        ALL columns. Per-column stopping freezes converged columns, so a
        mixed batch costs max-over-columns iterations, not the sum.

        ``loads``: (n_rhs, S_real, n) host stack of per-subdomain load
        vectors in original DOF order (a single (S_real, n) case is
        promoted to a 1-batch). ``rhs_unit`` > 1 pads the batch with
        zero-load dummy columns up to a multiple of that unit — zero
        columns converge at iteration 0, so padding costs only the block
        width — keeping compiled-shape reuse under control for ragged
        request streams; the padding is stripped from the result.

        A 1-column batch dispatches through the exact single-RHS
        :meth:`solve` program, so its result is bit-identical to
        ``solve(loads=...)``.
        """
        if self.state is None:
            self.preprocess()
        st = self.state
        prob = self.problem
        loads = np.asarray(loads)
        if loads.ndim == 2:
            loads = loads[None]
        S_real, n = st.S_real, prob.subdomains[0].n
        if loads.ndim != 3 or loads.shape[1:] != (S_real, n):
            raise ValueError(
                f"loads must be (n_rhs, {S_real}, {n}) "
                f"(or one (S_real, n) case), got {loads.shape}")
        if rhs_unit < 1:
            raise ValueError(f"rhs_unit must be >= 1, got {rhs_unit}")
        n_rhs = loads.shape[0]
        r_pad = -(-n_rhs // rhs_unit) * rhs_unit

        if r_pad == 1:
            sol = self.solve(tol=tol, max_iter=max_iter, loads=loads[0])
            self.timings["solve_many_s"] = self.timings["solve_s"]
            self.timings["per_solve_s"] = self.timings["solve_s"]
            return FetiManySolution(
                u=sol.u[None], u_global=sol.u_global[None],
                lam=sol.lam[None], alpha=sol.alpha[None],
                iterations=np.asarray([sol.iterations]),
                residuals=np.asarray([sol.residual]),
                converged=np.asarray([sol.converged]),
                block_iterations=sol.iterations,
                n_rhs=1, n_rhs_padded=1, timings=dict(self.timings),
            )

        ops = self._solution_ops()
        coarse = ops.coarse
        t0 = time.perf_counter()
        if r_pad > n_rhs:
            loads = np.concatenate(
                [loads, np.zeros((r_pad - n_rhs, S_real, n), loads.dtype)])
        # column-stacked device layout: (S, n, n_rhs), load case last
        F_dev, Fp_dev = self._load_stacks(loads.transpose(1, 2, 0))
        D = ops.dual_rhs_cols(Fp_dev)
        Lam0 = coarse.lambda0(ops.coarse_e_cols(F_dev))
        jax.block_until_ready(D)
        self.timings["rhs_setup_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        run = self._many_run(tol, max_iter)
        res: PCPGManyResult = run(D, Lam0)
        jax.block_until_ready(res.lam)
        t_solve = time.perf_counter() - t0
        self.timings["solve_many_s"] = t_solve
        self.timings["per_solve_s"] = t_solve / n_rhs

        # ---- recover α and u per column (paper eqs. 5, 7) ----
        t0 = time.perf_counter()
        Flam = ops.apply_F_many(res.lam)
        alpha_flat = coarse.alpha(Flam - D)  # (S·k, r), subdomain-major
        lam_loc = gather_local(res.lam, st.lambda_ids)  # (S, m_max, r)
        rhs = Fp_dev - jnp.einsum("snm,smr->snr", st.Btp, lam_loc)
        up = solve_with_factor_many(st.L, rhs)
        u, alpha, u_global = self._recover_u(up, alpha_flat, r_pad)
        self.timings["recover_s"] = time.perf_counter() - t0

        keep = slice(0, n_rhs)  # strip rhs_unit padding columns
        return FetiManySolution(
            u=u[keep], u_global=u_global[keep],
            lam=np.asarray(res.lam).T[keep],
            alpha=alpha[keep],
            iterations=np.asarray(res.iterations)[keep],
            residuals=np.asarray(res.residual)[keep],
            converged=np.asarray(res.converged)[keep],
            block_iterations=int(res.block_iterations),
            n_rhs=n_rhs, n_rhs_padded=r_pad,
            timings=dict(self.timings),
        )

    # ---- amortization (paper §5, Fig. 10) ----
    def amortization_report(self, t_assembly_s: float, t_implicit_iter_s: float,
                            t_explicit_iter_s: float,
                            t_dirichlet_s: float = 0.0,
                            n_rhs: int = 1,
                            iters_per_solve: Optional[float] = None) -> dict:
        """Iterations needed before the explicit approach wins (paper §1).

        ``t_dirichlet_s`` is the extra preprocessing spent assembling the
        Dirichlet preconditioner's boundary Schur complements (zero when
        preconditioner != "dirichlet"); it goes into the numerator — the
        stage pays for itself through *fewer* iterations, but its wall
        time still delays the break-even point of the explicit operator.

        Multi-RHS extension (ISSUE 6): with ``n_rhs`` > 1 the iteration
        times are understood as *block* iteration times on an
        (n_lambda, n_rhs) stack, so ``amortization_iterations`` stays the
        block-iteration break-even. Passing ``iters_per_solve`` (the
        typical PCPG iteration count of one load case) additionally
        reports ``amortization_solves`` — the number of *load cases*
        after which explicit assembly has paid for itself: each batch of
        ``n_rhs`` cases costs ~``iters_per_solve`` block iterations, so
        break-even solves = break-even iterations / iters_per_solve ·
        n_rhs. The analytic per-iteration cost model
        (:func:`repro.launch.analytic.feti_solve_iter_counts`, shared
        with the dry-run cells) is attached per n_rhs.
        """
        gain = t_implicit_iter_s - t_explicit_iter_s
        overhead = t_assembly_s + t_dirichlet_s
        point = float("inf") if gain <= 0 else overhead / gain
        amort_solves = None
        if iters_per_solve is not None and iters_per_solve > 0:
            amort_solves = point / iters_per_solve * n_rhs
        iter_counts = None
        if self.state is not None:
            from repro.launch.analytic import feti_solve_iter_counts

            iter_counts = feti_solve_iter_counts(
                self.state.S_real, self.problem.m_max, n_rhs=n_rhs,
                fb=np.dtype(self.dtype).itemsize)
        flops = assembly_flops(self.state.env, self.cfg) if self.state else None
        d_flops = None
        st = self.state
        if st is not None and st.dirichlet_env is not None:
            from repro.sparse.cholesky import block_cholesky_flops

            d_flops = assembly_flops(st.dirichlet_env, st.dirichlet_cfg)
            d_flops = dict(d_flops)
            chol_ii = block_cholesky_flops(
                st.split.n_i, st.dirichlet_cfg.block_size, st.dirichlet_mask)
            # the stage-graph factor dedup elides the interior
            # factorization — the dual factor already holds it
            d_flops["cholesky_ii"] = 0.0 if st.shared_factor else chol_ii
            d_flops["cholesky_ii_saved_by_sharing"] = (
                chol_ii if st.shared_factor else 0.0)
            d_flops["total"] += d_flops["cholesky_ii"]
        return {
            "amortization_iterations": point,
            "amortization_solves": amort_solves,
            "n_rhs": int(n_rhs),
            "assembly_s": t_assembly_s,
            "dirichlet_s": t_dirichlet_s,
            "implicit_iter_s": t_implicit_iter_s,
            "explicit_iter_s": t_explicit_iter_s,
            "assembly_flops_per_subdomain": flops,
            "dirichlet_flops_per_subdomain": d_flops,
            "solve_iter_counts": iter_counts,
        }


def solve_many(problem: FetiProblem, loads, config=None, *,
               tol: float = 1e-9, max_iter: int = 2000,
               rhs_unit: int = 1) -> FetiManySolution:
    """One-shot multi-load solve: preprocess once, block-PCPG the batch.

    The functional front door for the server-style workload when no solver
    object needs to outlive the call: ``solve_many(problem, loads,
    FetiConfig(...))`` is exactly ``FetiSolver(problem, config)
    .solve_many(loads, ...)``. Callers streaming many batches against one
    preprocessing should hold a :class:`FetiSolver` instead.
    """
    return FetiSolver(problem, config).solve_many(
        loads, tol=tol, max_iter=max_iter, rhs_unit=rhs_unit)
