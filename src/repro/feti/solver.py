"""End-to-end FETI solver (paper §2 + §5).

Stages exactly as the paper defines them:
  initialization —  symbolic factorization & persistent structures
                    (inside :func:`repro.feti.assembly.preprocess_cluster`),
  preprocessing  —  numerical factorization + explicit SC assembly,
  solution       —  PCPG iterations applying the dual operator.

``FetiSolver(mode=...)`` selects the implicit (eq. 11) or explicit (eq. 12)
dual operator; ``amortization_report`` computes the iteration count at which
the explicit approach pays off — the paper's central figure of merit
(Fig. 10: ≈10 iterations with the sparsity-utilizing assembly).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SchurAssemblyConfig, assembly_flops
from repro.feti.assembly import ClusterState, preprocess_cluster
from repro.feti.operator import (
    dirichlet_preconditioner,
    dual_rhs,
    explicit_dual_apply,
    gather_local,
    implicit_dual_apply,
    lumped_preconditioner,
    solve_with_factor,
)
from repro.feti.pcpg import PCPGResult, pcpg
from repro.feti.projector import build_coarse_problem
from repro.fem.decomposition import FetiProblem

__all__ = ["FetiSolver", "FetiSolution", "PRECONDITIONERS"]

PRECONDITIONERS = ("lumped", "dirichlet", "none")


@dataclasses.dataclass
class FetiSolution:
    u: np.ndarray  # (S, n) subdomain solutions, original DOF order
    u_global: np.ndarray  # (n_global_dofs,) averaged onto the global mesh
    lam: np.ndarray
    alpha: np.ndarray  # (S, k) kernel coefficients per subdomain
    iterations: int
    residual: float
    converged: bool
    timings: dict


class FetiSolver:
    """Drives preprocess + PCPG for one cluster (batched subdomains)."""

    def __init__(
        self,
        problem: FetiProblem,
        cfg: Union[SchurAssemblyConfig, str, None] = None,
        mode: str = "explicit",
        preconditioner: str = "lumped",
        ordering: str = "nd",
        dtype=jnp.float64,
        measure: str = "auto",
        plan_cache: bool = True,
        mesh=None,
        storage: Optional[str] = None,
    ):
        """``cfg`` may also be the string ``"auto"``: the assembly plan is
        then chosen by the autotuner during :meth:`preprocess` (see
        :mod:`repro.core.autotune`) and ``self.cfg``/``self.plan`` carry
        the resolved config and its cost report afterwards. ``measure``
        and ``plan_cache`` tune that search and are ignored otherwise.

        ``storage`` ("dense" | "packed" | None) overrides the factor
        storage layout (see :func:`repro.feti.assembly.preprocess_cluster`);
        with ``cfg="auto"`` it restricts the autotuner's search to that
        layout, and ``None`` lets the tuner choose.

        ``mesh`` (a ``("data",)`` device mesh, see
        :func:`repro.launch.mesh.make_feti_mesh`) shards the subdomain
        axis over devices: preprocessing partitions per-device and the
        PCPG operators run under shard_map with psum exchange
        (:mod:`repro.feti.sharded`). ``mesh=None`` keeps today's
        single-device batched behavior bit-for-bit."""
        if mode not in ("explicit", "implicit"):
            raise ValueError("mode must be 'explicit' or 'implicit'")
        if preconditioner not in PRECONDITIONERS:
            raise ValueError(
                f"preconditioner must be one of {PRECONDITIONERS}, "
                f"got {preconditioner!r}")
        self.problem = problem
        self.cfg = cfg if cfg is not None else SchurAssemblyConfig()
        self.plan = None
        self.mode = mode
        self.preconditioner = preconditioner
        self.ordering = ordering
        self.dtype = dtype
        self.measure = measure
        self.plan_cache = plan_cache
        self.mesh = mesh
        self.storage = storage
        self.state: Optional[ClusterState] = None
        self.timings: dict = {}

    # ---- preprocessing (paper §2.2) ----
    def preprocess(self) -> ClusterState:
        t0 = time.perf_counter()
        self.state = preprocess_cluster(
            self.problem,
            self.cfg,
            explicit=(self.mode == "explicit"),
            ordering=self.ordering,
            dtype=self.dtype,
            measure=self.measure,
            plan_cache=self.plan_cache,
            mesh=self.mesh,
            storage=self.storage,
            dirichlet=(self.preconditioner == "dirichlet"),
        )
        jax.block_until_ready(self.state.L)
        if self.state.F is not None:
            jax.block_until_ready(self.state.F)
        if self.state.Sb is not None:
            jax.block_until_ready(self.state.Sb)
        self.cfg = self.state.cfg  # resolved when "auto" was passed
        self.plan = self.state.plan
        self.timings["preprocess_s"] = time.perf_counter() - t0
        return self.state

    # ---- solution (paper §2.2) ----
    def solve(self, tol: float = 1e-9, max_iter: int = 2000) -> FetiSolution:
        if self.state is None:
            self.preprocess()
        st = self.state
        prob = self.problem
        nl = prob.n_lambda
        c = jnp.asarray(prob.c, dtype=self.dtype)
        Bt_host = np.stack([sd.Bt for sd in prob.subdomains])

        if st.mesh is None:
            Bt_orig = jnp.asarray(Bt_host, dtype=self.dtype)
            coarse = build_coarse_problem(
                Bt_orig, st.f, st.R, st.lambda_ids, nl
            )
            if self.mode == "explicit":
                apply_F = partial(explicit_dual_apply, st.F, st.lambda_ids,
                                  nl)
            else:
                apply_F = partial(implicit_dual_apply, st.L, st.Btp,
                                  st.lambda_ids, nl)
            # K is packed in factor row order, so it pairs with Btp (the
            # product B̃ K B̃ᵀ is invariant to the shared row permutation)
            precond_args = (st.K, st.Btp, st.lambda_ids, nl)
            precond_fn = lumped_preconditioner
            dirichlet_args = (st.Sb, st.Btb, st.lambda_ids, nl)
            dirichlet_fn = dirichlet_preconditioner
            d = dual_rhs(st.L, st.Btp, st.fp, st.lambda_ids, nl, c)
        else:
            from repro.feti import sharded as shlib

            # match the state's relabeled multiplier columns, pad the
            # dummy subdomains (zero gluing), and shard like the stacks
            Bt_rel = shlib.relabel_columns(Bt_host, np.asarray(st.col_perm))
            Bt_orig = shlib.shard_stack(
                st.mesh, np.asarray(shlib.pad_stack(Bt_rel, st.S),
                                    dtype=self.dtype))
            coarse = shlib.build_coarse_problem(
                st.mesh, Bt_orig, st.f, st.R, st.lambda_ids, nl,
                S_real=st.S_real,
            )
            if self.mode == "explicit":
                apply_F = partial(shlib.explicit_dual_apply, st.mesh, st.F,
                                  st.lambda_ids, nl)
            else:
                apply_F = partial(shlib.implicit_dual_apply, st.mesh, st.L,
                                  st.Btp, st.lambda_ids, nl)
            precond_args = (st.mesh, st.K, st.Btp, st.lambda_ids, nl)
            precond_fn = shlib.lumped_preconditioner
            dirichlet_args = (st.mesh, st.Sb, st.Btb, st.lambda_ids, nl)
            dirichlet_fn = shlib.dirichlet_preconditioner
            d = shlib.dual_rhs(st.mesh, st.L, st.Btp, st.fp, st.lambda_ids,
                               nl, c)

        if self.preconditioner == "lumped":
            precond = partial(precond_fn, *precond_args)
        elif self.preconditioner == "dirichlet":
            if st.Sb is None:
                raise ValueError(
                    "state was preprocessed without the dirichlet stage; "
                    "construct the solver with preconditioner='dirichlet' "
                    "before preprocess()")
            precond = partial(dirichlet_fn, *dirichlet_args)
        elif self.preconditioner == "none":
            precond = None
        else:
            raise ValueError(f"unknown preconditioner {self.preconditioner!r}")

        lam0 = coarse.lambda0()

        t0 = time.perf_counter()
        run = jax.jit(
            lambda d_, lam0_: pcpg(
                apply_F, coarse.project, d_, lam0_,
                precondition=precond, tol=tol, max_iter=max_iter,
                mesh=st.mesh,
            )
        )
        res: PCPGResult = run(d, lam0)
        jax.block_until_ready(res.lam)
        self.timings["solve_s"] = time.perf_counter() - t0

        # ---- recover α and u (paper eqs. 5, 7) ----
        Flam = apply_F(res.lam)
        alpha = coarse.alpha(Flam - d)  # (S·k,), subdomain-major
        lam_loc = gather_local(res.lam, st.lambda_ids)
        rhs = st.fp - jnp.einsum("snm,sm->sn", st.Btp, lam_loc)
        up = solve_with_factor(st.L, rhs)
        # back to original DOF order + kernel (rigid-body) correction
        # u_i = K⁺(f − Bᵀλ)_i + R_i α_i; drop any inert mesh-padding
        # subdomains (S_real == S unsharded)
        k = st.R.shape[2]
        inv_perm = np.argsort(st.node_perm)
        up_h = np.asarray(up)[: st.S_real]
        alpha = np.asarray(alpha).reshape(st.S, k)[: st.S_real]
        R_h = np.asarray(st.R)[: st.S_real]
        u = up_h[:, inv_perm] + np.einsum("snk,sk->sn", R_h, alpha)

        # average duplicated interface copies onto the global mesh (DOFs)
        nn = prob.n_global_dofs
        acc = np.zeros(nn)
        cnt = np.zeros(nn)
        for i, sd in enumerate(prob.subdomains):
            np.add.at(acc, sd.dof_gids, u[i])
            np.add.at(cnt, sd.dof_gids, 1.0)
        u_global = acc / np.maximum(cnt, 1.0)

        return FetiSolution(
            u=u,
            u_global=u_global,
            lam=np.asarray(res.lam),
            alpha=np.asarray(alpha),
            iterations=int(res.iterations),
            residual=float(res.residual),
            converged=bool(res.converged),
            timings=dict(self.timings),
        )

    # ---- amortization (paper §5, Fig. 10) ----
    def amortization_report(self, t_assembly_s: float, t_implicit_iter_s: float,
                            t_explicit_iter_s: float,
                            t_dirichlet_s: float = 0.0) -> dict:
        """Iterations needed before the explicit approach wins (paper §1).

        ``t_dirichlet_s`` is the extra preprocessing spent assembling the
        Dirichlet preconditioner's boundary Schur complements (zero when
        preconditioner != "dirichlet"); it goes into the numerator — the
        stage pays for itself through *fewer* iterations, but its wall
        time still delays the break-even point of the explicit operator.
        """
        gain = t_implicit_iter_s - t_explicit_iter_s
        overhead = t_assembly_s + t_dirichlet_s
        point = float("inf") if gain <= 0 else overhead / gain
        flops = assembly_flops(self.state.env, self.cfg) if self.state else None
        d_flops = None
        st = self.state
        if st is not None and st.dirichlet_env is not None:
            from repro.sparse.cholesky import block_cholesky_flops

            d_flops = assembly_flops(st.dirichlet_env, st.dirichlet_cfg)
            d_flops = dict(d_flops)
            d_flops["cholesky_ii"] = block_cholesky_flops(
                st.split.n_i, st.dirichlet_cfg.block_size, st.dirichlet_mask)
            d_flops["total"] += d_flops["cholesky_ii"]
        return {
            "amortization_iterations": point,
            "assembly_s": t_assembly_s,
            "dirichlet_s": t_dirichlet_s,
            "implicit_iter_s": t_implicit_iter_s,
            "explicit_iter_s": t_explicit_iter_s,
            "assembly_flops_per_subdomain": flops,
            "dirichlet_flops_per_subdomain": d_flops,
        }
