"""Distributed FETI: the subdomain axis sharded over a ``("data",)`` mesh.

The single-device pipeline batches all subdomains of a cluster through one
compiled program with a leading subdomain axis (feti/assembly.py). This
module is the multi-node story that docstring promises: the same stacks,
placed with ``NamedSharding(P("data"))`` so each device owns a contiguous
slice of subdomains, and the solution-phase operators moved under
``shard_map`` where the per-subdomain scatter into multiplier (λ) space
becomes a ``psum`` over the subdomain-sharded axis — the JAX analogue of
the MPI neighbour exchange in the paper's CUDA predecessor (Homola et al.,
arXiv:2502.08382) and of classic GPU-cluster sub-structuring (Cheik Ahamed
& Magoulès, arXiv:2108.13162).

Design notes:

* **Relabeled multipliers.** Under sharding the per-subdomain stepped
  *column* permutations of B̃ᵀ would be batched runtime gathers, which
  GSPMD can only partition by replicating the gather operand. The local
  multiplier order is arbitrary, so preprocessing relabels columns
  host-side once (B̃ᵀ, ``lambda_ids`` and the explicit SC all move to
  stepped order together) and the assembler runs its ``col_perm=None``
  fast path — zero runtime permutes, perfectly partitionable. λ-space
  results are unchanged because gather/scatter use the relabeled ids.
* **Padding.** The subdomain count is padded up to a multiple of the mesh
  size with identity-stiffness / zero-gluing dummies whose multiplier ids
  all point at the scatter's dummy slot: they factorize to identity,
  assemble to zero, and contribute exactly nothing to any psum.
* **Replicated λ.** Dual vectors (length ``n_lambda``) stay replicated on
  every device; only the subdomain-stacked arrays are sharded. PCPG is
  unchanged — it sees the same functional operator signatures.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.feti import operator as op
from repro.feti import projector as proj
from repro.feti.projector import CoarseProblem, coarse_factor, coarse_g_e

try:  # jax >= 0.4.35 re-exports shard_map from the top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent import
    from jax.experimental.shard_map import shard_map

__all__ = [
    "AXIS",
    "ShardedCoarseProblem",
    "build_coarse_problem",
    "coarse_e",
    "coarse_e_many",
    "data_sharding",
    "dirichlet_preconditioner",
    "dirichlet_preconditioner_many",
    "dual_rhs",
    "dual_rhs_many",
    "explicit_dual_apply",
    "explicit_dual_apply_many",
    "implicit_dual_apply",
    "implicit_dual_apply_many",
    "lumped_preconditioner",
    "lumped_preconditioner_many",
    "mesh_size",
    "pad_stack",
    "padded_count",
    "relabel_columns",
    "replicated_sharding",
    "shard_stack",
]

AXIS = "data"  # the one mesh axis FETI shards over (see launch/mesh.py)


# --------------------------------------------------------------------------
# placement helpers
# --------------------------------------------------------------------------

def mesh_size(mesh: Mesh) -> int:
    """Number of devices along the FETI ``data`` axis."""
    if AXIS not in mesh.axis_names:
        raise ValueError(
            f"FETI sharding needs a {AXIS!r} mesh axis, got {mesh.axis_names}"
        )
    return mesh.shape[AXIS]


def data_sharding(mesh: Mesh) -> NamedSharding:
    """Shard the leading (subdomain) axis; replicate the rest."""
    return NamedSharding(mesh, P(AXIS))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def padded_count(S: int, mesh: Mesh) -> int:
    """Subdomain count padded up to a multiple of the mesh size."""
    D = mesh_size(mesh)
    return -(-S // D) * D


def pad_stack(x: np.ndarray, S_pad: int, identity: bool = False) -> np.ndarray:
    """Pad a (S, ...) stack to (S_pad, ...) subdomains.

    ``identity=True`` pads square-matrix stacks with identity matrices so
    dummy subdomains stay factorizable; the default zero padding is right
    for gluing/load/SC stacks (dummies then contribute nothing).
    """
    S = x.shape[0]
    if S_pad < S:
        raise ValueError(f"cannot pad {S} subdomains down to {S_pad}")
    if S_pad == S:
        return x
    if identity:
        n = x.shape[1]
        pad = np.broadcast_to(np.eye(n, dtype=x.dtype), (S_pad - S, n, n))
    else:
        pad = np.zeros((S_pad - S,) + x.shape[1:], dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


def shard_stack(mesh: Mesh, x) -> jax.Array:
    """Place a host stack on the mesh, subdomain axis sharded over AXIS."""
    return jax.device_put(jnp.asarray(x), data_sharding(mesh))


def relabel_columns(stack: np.ndarray, col_perm: np.ndarray) -> np.ndarray:
    """Apply each subdomain's stepped column permutation host-side.

    ``stack`` is (S, ..., m_max) with multiplier columns last; ``col_perm``
    is (S, m_max). Returns ``out[s, ..., j] = stack[s, ..., col_perm[s, j]]``
    — the once-per-pattern relabeling that lets the runtime assembler and
    dual operator skip per-subdomain permutes entirely.
    """
    idx = col_perm.reshape(
        (col_perm.shape[0],) + (1,) * (stack.ndim - 2) + (col_perm.shape[1],)
    )
    return np.take_along_axis(stack, idx, axis=-1)


# --------------------------------------------------------------------------
# the dual operator & friends under shard_map
# --------------------------------------------------------------------------
#
# Each wrapper reuses the batched single-device implementation from
# feti/operator.py as the *per-shard* body: inside shard_map the scatter
# lands in a device-local (n_lambda,) buffer holding this shard's partial
# subdomain sums, and the trailing psum over AXIS completes the additive
# dual assembly. λ inputs/outputs are replicated.

def explicit_dual_apply(
    mesh: Mesh,
    F: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    lam: jax.Array,
) -> jax.Array:
    """q = Σᵢ scatter(F̃ᵢ gather(λ)) with the Σ as a psum (paper eq. 12)."""

    def body(F_l, ids_l, lam_r):
        q = op.explicit_dual_apply(F_l, ids_l, n_lambda, lam_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body, mesh=mesh, in_specs=(P(AXIS), P(AXIS), P()), out_specs=P()
    )(F, lambda_ids, lam)


def implicit_dual_apply(
    mesh: Mesh,
    L: jax.Array,
    Btp: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    lam: jax.Array,
) -> jax.Array:
    """q = Σᵢ scatter(B̃ᵢ L⁻ᵀL⁻¹ B̃ᵢᵀ gather(λ)), Σ as psum (paper eq. 11)."""

    def body(L_l, B_l, ids_l, lam_r):
        q = op.implicit_dual_apply(L_l, B_l, ids_l, n_lambda, lam_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=P(),
    )(L, Btp, lambda_ids, lam)


def lumped_preconditioner(
    mesh: Mesh,
    K: jax.Array,
    Bt: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    w: jax.Array,
) -> jax.Array:
    """Lumped FETI preconditioner M⁻¹ ≈ Σᵢ B̃ᵢ Kᵢ B̃ᵢᵀ, Σ as psum."""

    def body(K_l, B_l, ids_l, w_r):
        q = op.lumped_preconditioner(K_l, B_l, ids_l, n_lambda, w_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=P(),
    )(K, Bt, lambda_ids, w)


def dirichlet_preconditioner(
    mesh: Mesh,
    Sb: jax.Array,
    Btb: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    w: jax.Array,
) -> jax.Array:
    """Dirichlet preconditioner M⁻¹ = Σᵢ B̃ᵢ S_b,i B̃ᵢᵀ, Σ as psum.

    ``Sb`` (the per-subdomain primal boundary Schur complements) and the
    boundary-row B̃ᵀ slice ``Btb`` are carried under the same ``P(AXIS)``
    specs as the explicit SC stack — padded dummy subdomains have zero
    ``Btb``, so whatever their (identity-padded) S_b is, they contribute
    exactly nothing to the psum.
    """

    def body(Sb_l, Bb_l, ids_l, w_r):
        q = op.dirichlet_preconditioner(Sb_l, Bb_l, ids_l, n_lambda, w_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=P(),
    )(Sb, Btb, lambda_ids, w)


def dual_rhs(
    mesh: Mesh,
    L: jax.Array,
    Btp: jax.Array,
    fp: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    c: jax.Array,
) -> jax.Array:
    """d = B K⁺ f − c; the B-scatter is psum'd, c subtracted once outside."""

    def body(L_l, B_l, f_l, ids_l):
        zero_c = jnp.zeros((n_lambda,), B_l.dtype)
        q = op.dual_rhs(L_l, B_l, f_l, ids_l, n_lambda, zero_c)
        return jax.lax.psum(q, AXIS)

    out = shard_map(
        body, mesh=mesh, in_specs=(P(AXIS),) * 4, out_specs=P()
    )(L, Btp, fp, lambda_ids)
    return out - c


# --------------------------------------------------------------------------
# multi-RHS column-stacked operators (ISSUE 6)
# --------------------------------------------------------------------------
#
# Same deployment as the single-RHS wrappers above — subdomain stacks
# sharded P(AXIS), multiplier stacks replicated P() — with the batched
# `_many` bodies of feti/operator.py per shard. A replicated rank-2
# (n_lambda, n_rhs) stack and an extra trailing column axis on the sharded
# (S, n, n_rhs) load stacks need no new specs: P(AXIS)/P() shard the
# leading axis and replicate everything else, whatever the rank.

def explicit_dual_apply_many(
    mesh: Mesh,
    F: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    Lam: jax.Array,
) -> jax.Array:
    """Eq. 12 on an (n_lambda, n_rhs) stack, the Σ over subdomains psum'd."""

    def body(F_l, ids_l, Lam_r):
        q = op.explicit_dual_apply_many(F_l, ids_l, n_lambda, Lam_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body, mesh=mesh, in_specs=(P(AXIS), P(AXIS), P()), out_specs=P()
    )(F, lambda_ids, Lam)


def implicit_dual_apply_many(
    mesh: Mesh,
    L: jax.Array,
    Btp: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    Lam: jax.Array,
) -> jax.Array:
    """Eq. 11 on an (n_lambda, n_rhs) stack, the Σ over subdomains psum'd."""

    def body(L_l, B_l, ids_l, Lam_r):
        q = op.implicit_dual_apply_many(L_l, B_l, ids_l, n_lambda, Lam_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=P(),
    )(L, Btp, lambda_ids, Lam)


def lumped_preconditioner_many(
    mesh: Mesh,
    K: jax.Array,
    Bt: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    W: jax.Array,
) -> jax.Array:
    """Lumped preconditioner on an (n_lambda, n_rhs) residual stack."""

    def body(K_l, B_l, ids_l, W_r):
        q = op.lumped_preconditioner_many(K_l, B_l, ids_l, n_lambda, W_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=P(),
    )(K, Bt, lambda_ids, W)


def dirichlet_preconditioner_many(
    mesh: Mesh,
    Sb: jax.Array,
    Btb: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    W: jax.Array,
) -> jax.Array:
    """Dirichlet preconditioner on an (n_lambda, n_rhs) residual stack."""

    def body(Sb_l, Bb_l, ids_l, W_r):
        q = op.dirichlet_preconditioner_many(Sb_l, Bb_l, ids_l, n_lambda, W_r)
        return jax.lax.psum(q, AXIS)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=P(),
    )(Sb, Btb, lambda_ids, W)


def dual_rhs_many(
    mesh: Mesh,
    L: jax.Array,
    Btp: jax.Array,
    Fp: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    c: jax.Array,
) -> jax.Array:
    """D = B K⁺ F − c1ᵀ for a sharded (S_pad, n, n_rhs) load-case stack;
    the B-scatter is psum'd, c broadcast-subtracted once outside."""

    def body(L_l, B_l, F_l, ids_l):
        t = op.solve_with_factor_many(L_l, F_l)
        q_loc = jnp.einsum("snm,snr->smr", B_l, t)
        q = op.scatter_dual(q_loc, ids_l, n_lambda)
        return jax.lax.psum(q, AXIS)

    out = shard_map(
        body, mesh=mesh, in_specs=(P(AXIS),) * 4, out_specs=P()
    )(L, Btp, Fp, lambda_ids)
    return out - c[:, None]


def coarse_e(mesh: Mesh, f: jax.Array, R: jax.Array) -> jax.Array:
    """e = Rᵀf from sharded (padded) stacks → replicated (S_pad·k,).

    The load-dependent half of the coarse problem for streamed load
    cases; padded subdomains have zero R, so their entries are zero."""
    out = shard_map(
        proj.coarse_e,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS)),
        out_specs=P(AXIS),
    )(f, R)
    return jax.device_put(out, replicated_sharding(mesh))


def coarse_e_many(mesh: Mesh, F: jax.Array, R: jax.Array) -> jax.Array:
    """e = RᵀF for a sharded (S_pad, n, n_rhs) load-case stack →
    replicated (S_pad·k, n_rhs), subdomain-major like G's columns."""
    out = shard_map(
        proj.coarse_e_many,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS)),
        out_specs=P(AXIS),
    )(F, R)
    return jax.device_put(out, replicated_sharding(mesh))


# --------------------------------------------------------------------------
# coarse problem with column-sharded G
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedCoarseProblem(CoarseProblem):
    """Natural coarse space with G = BR column-sharded over subdomains.

    ``G`` keeps each (padded) subdomain's k kernel columns on that
    subdomain's device — shape (n_lambda, S_pad·k), columns sharded over
    AXIS in subdomain-major order; the tiny (S_pad·k, S_pad·k) Gram
    Cholesky factor and e = Rᵀf are replicated
    (``solve_coarse`` is inherited unchanged). The projector applications
    split into a communication-free local Gᵀx (columns are disjoint) and a
    psum'd G·t — the same exchange pattern as the dual operator.
    """

    mesh: Mesh

    def _gt_x(self, x: jax.Array) -> jax.Array:
        """Gᵀ x: per-shard local matvec, no exchange (disjoint columns)."""
        return shard_map(
            lambda G_l, x_r: G_l.T @ x_r,
            mesh=self.mesh,
            in_specs=(P(None, AXIS), P()),
            out_specs=P(AXIS),
        )(self.G, x)

    def _g_t(self, t: jax.Array) -> jax.Array:
        """G t: per-shard partial sums completed by a psum over AXIS."""
        return shard_map(
            lambda G_l, t_l: jax.lax.psum(G_l @ t_l, AXIS),
            mesh=self.mesh,
            in_specs=(P(None, AXIS), P(AXIS)),
            out_specs=P(),
        )(self.G, t)

    def project(self, x: jax.Array) -> jax.Array:
        """P x = x − G (GᵀG)⁻¹ Gᵀ x."""
        return x - self._g_t(self.solve_coarse(self._gt_x(x)))

    def lambda0(self, e: jax.Array = None) -> jax.Array:
        """Feasible start: λ⁰ = G(GᵀG)⁻¹e satisfies Gᵀλ⁰ = e.

        ``e`` overrides the cached load moment — a replicated (S_pad·k,)
        vector or (S_pad·k, n_rhs) stack (see :func:`coarse_e` /
        :func:`coarse_e_many`); ``_g_t`` broadcasts the extra column axis
        through its per-shard partial sums unchanged."""
        return self._g_t(self.solve_coarse(self.e if e is None else e))

    def alpha(self, Flam_minus_d: jax.Array) -> jax.Array:
        """α = (GᵀG)⁻¹Gᵀ(Fλ − d); padded entries come out exactly zero."""
        return self.solve_coarse(self._gt_x(Flam_minus_d))


def build_coarse_problem(
    mesh: Mesh,
    Bt: jax.Array,
    f: jax.Array,
    R: jax.Array,
    lambda_ids: jax.Array,
    n_lambda: int,
    S_real: int,
) -> ShardedCoarseProblem:
    """Assemble G = BR and e = Rᵀf from subdomain-sharded (padded) stacks.

    ``R`` is the (S_pad, n, k) kernel-basis stack (zero for padding).
    Padded subdomains have zero B̃ᵀ and zero load, so their G columns and
    e entries are exactly zero; the QR-derived coarse factor
    (:func:`repro.feti.projector.coarse_factor`, computed once here —
    GSPMD gathers the sharded columns for the setup-only QR) gives those
    zero columns a unit pivot, so the padded α components stay exactly
    zero through both triangular solves, and the leading block of the
    factor is bit-identical to the unpadded single-device one (Householder
    QR processes columns left to right; the trailing zero columns touch
    nothing before them).
    """

    def body(Bt_l, f_l, R_l, ids_l):
        return coarse_g_e(Bt_l, f_l, R_l, ids_l, n_lambda)

    G, e = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(AXIS),) * 4,
        out_specs=(P(None, AXIS), P(AXIS)),
    )(Bt, f, R, lambda_ids)

    chol = jax.device_put(coarse_factor(G), replicated_sharding(mesh))
    e = jax.device_put(e, replicated_sharding(mesh))
    return ShardedCoarseProblem(mesh=mesh, G=G, GtG_chol=chol, e=e)
