"""Dirichlet preconditioner: the *primal* boundary/interior Schur pipeline.

The FETI Dirichlet preconditioner

    M⁻¹ = Σᵢ B̃ᵢ S_b,i B̃ᵢᵀ,   S_b = K_bb − K_bi K_ii⁻¹ K_ib

is a second family of Schur complements, assembled per subdomain onto the
*boundary* DOFs (the rows B̃ᵀ touches) instead of onto the multipliers
(ESPRESO lineage: Homola et al., "Assembly of the FETI dual operator using
CUDA", arXiv:2502.08382). With L_ii the Cholesky factor of K_ii,

    K_bi K_ii⁻¹ K_ib = (L_ii⁻¹ K_ib)ᵀ (L_ii⁻¹ K_ib)

is exactly the TRSM+SYRK product the dual-operator assembly computes
(paper eq. 14) with K_ib as the sparse right-hand side — so this module
*reuses* :func:`repro.core.schur.make_assembler` verbatim: the interior
gets its own fill-reducing ordering and symbolic block fill mask, K_ib gets
its own stepped column metadata, and the whole dense/packed × TRSM/SYRK ×
block-size × Pallas design space (and the autotuner that searches it)
applies to the preconditioner stage unchanged.

Everything here is host-side symbolic analysis plus jit-friendly builders;
:func:`repro.feti.assembly.preprocess_cluster` threads them into the
batched (and optionally ``shard_map``-sharded) preprocessing program, and
:func:`repro.feti.operator.dirichlet_preconditioner` applies the stored
S_b stack inside PCPG. See docs/preconditioners.md for the cost model and
when the extra assembly amortizes.

Conventions:

* **Boundary** = every DOF carrying a B̃ᵀ row in *any* subdomain of the
  cluster (all subdomains share one local topology, so the split is shared
  and the cluster batches through one compiled program). Gluing is
  per-node-copy, so for vector problems the split is node-blocked: all
  ``ndof_per_node`` components of a node land on the same side.
* **Interior** DOFs are ordered by the restriction of the subdomain's
  fill-reducing node ordering (:mod:`repro.sparse.ordering`); boundary
  DOFs keep their original (node-blocked) order, so ``B̃ᵀ[boundary]``
  needs no column bookkeeping beyond the row restriction.
* A subdomain at the cluster's outer surface has faces the union classes
  as boundary but that carry none of ITS multipliers. The true Dirichlet
  preconditioner eliminates those too, so after the shared sparse
  assembly a per-subdomain **own-boundary restriction** (Schur complements
  compose) eliminates each subdomain's spurious boundary DOFs as a dense
  batched epilogue — the per-subdomain variation lives in a 0/1 *value*
  mask, never in the compiled structure
  (:func:`restrict_own_boundary`). Measured on the elasticity oracle
  cases this is what pushes the Dirichlet iteration counts strictly below
  lumped's (docs/preconditioners.md §Own-boundary).
* S_b is assembled from the **unregularized** K — K_ii is SPD outright
  (a rigid mode vanishing on the whole boundary is zero), and the
  fixing-DOF regularization would perturb S_b by ρ on boundary diagonal
  entries (elasticity places its fixing DOFs on corner nodes), measurably
  degrading the preconditioner. Assembling from a regularized K remains
  supported for the SPD-variant tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SchurAssemblyConfig, build_stepped_meta, make_assembler
from repro.core.stepped import SteppedMeta, column_pivots
from repro.fem.decomposition import FetiProblem
from repro.fem.meshgen import structured_mesh
from repro.sparse import (
    block_pattern,
    block_symbolic_cholesky,
    matrix_pattern_from_elems,
    node_ordering,
)
from repro.sparse.cholesky import block_cholesky
from repro.sparse.packed import PackedBlockIndex, block_cholesky_packed

__all__ = [
    "BoundaryInteriorSplit",
    "boundary_interior_split",
    "dirichlet_symbolic",
    "make_dirichlet_assembler",
    "own_boundary_masks",
    "restrict_own_boundary",
    "assemble_dirichlet_schur",
    "dirichlet_fingerprint",
]


@dataclasses.dataclass(frozen=True)
class BoundaryInteriorSplit:
    """The shared boundary/interior partition of one cluster's local DOFs.

    ``interior`` is already in the interior fill-reducing elimination
    order; ``boundary`` is in ascending original (node-blocked) DOF order.
    ``dperm = [interior; boundary]`` is the row/column permutation that
    brings every subdomain's K into the 2x2 primal Schur layout.
    """

    n: int  # local DOFs per subdomain
    interior: np.ndarray  # (n_i,) original DOF ids, fill-reducing order
    boundary: np.ndarray  # (n_b,) original DOF ids, ascending

    @property
    def n_i(self) -> int:
        return len(self.interior)

    @property
    def n_b(self) -> int:
        return len(self.boundary)

    @property
    def dperm(self) -> np.ndarray:
        return np.concatenate([self.interior, self.boundary])

    def validate_partition(self) -> None:
        """boundary ∪ interior = all DOFs, disjoint (tested property)."""
        both = np.concatenate([self.interior, self.boundary])
        if len(both) != self.n or len(np.unique(both)) != self.n:
            raise ValueError("boundary/interior do not partition the DOFs")


def boundary_interior_split(
    problem: FetiProblem,
    ordering: str = "nd",
    dof_perm: Optional[np.ndarray] = None,
) -> BoundaryInteriorSplit:
    """Classify the cluster's local DOFs as boundary (any B̃ᵀ row across
    the cluster's subdomains) vs interior, node-blocked for vector DOFs.

    Using the *union* over subdomains keeps the split (and with it the
    symbolic products and the compiled program) shared: a superset of one
    subdomain's true boundary only grows its S_b — applying B̃ S_b B̃ᵀ
    still reads exactly the rows that subdomain's B̃ᵀ touches.

    ``dof_perm`` is the expanded fill-reducing DOF permutation. The
    cluster preprocessor passes the ONE it already computed (the stage
    graph computes each symbolic product exactly once — this function used
    to silently rebuild ``node_ordering`` + ``expand_node_perm``, a
    duplication that could drift); ``dof_perm=None`` rebuilds it from
    ``ordering`` for standalone use and must produce the identical order.
    """
    subs = problem.subdomains
    n = subs[0].n
    ndpn = problem.ndof_per_node
    bmask = np.zeros(n, dtype=bool)
    for sd in subs:
        bmask[sd.b_rows[: sd.m]] = True
    if ndpn > 1:
        # node-blocked closure (gluing/pinning is per node copy, so this is
        # a no-op on well-formed decompositions — but it guarantees the
        # packed layout's node blocks never straddle the split)
        node_b = bmask.reshape(-1, ndpn).any(axis=1)
        bmask = np.repeat(node_b, ndpn)
    if not bmask.any():
        raise ValueError("no boundary DOFs: the decomposition has no "
                         "multipliers, so there is nothing to precondition")

    if dof_perm is None:
        node_shape = tuple(e + 1 for e in problem.elems_per_sub)
        nperm = node_ordering(node_shape, ordering)
        from repro.feti.assembly import expand_node_perm

        dof_perm = expand_node_perm(nperm, ndpn)
    elif len(dof_perm) != n:
        raise ValueError(f"dof_perm has {len(dof_perm)} entries for {n} "
                         "local DOFs")
    # restriction of the fill-reducing order to the interior subgraph:
    # interior nodes keep their relative elimination order, which preserves
    # the separator structure (and hence the low fill) on the sub-box
    interior = dof_perm[~bmask[dof_perm]]
    boundary = np.flatnonzero(bmask).astype(np.int64)
    split = BoundaryInteriorSplit(n=n, interior=interior, boundary=boundary)
    split.validate_partition()
    return split


def _local_dof_pattern(problem: FetiProblem) -> np.ndarray:
    """Dense boolean pattern of one subdomain's K in original DOF order."""
    from repro.feti.assembly import expand_node_pattern

    ndpn = problem.ndof_per_node
    lmesh = structured_mesh(problem.elems_per_sub)
    npat = matrix_pattern_from_elems(lmesh.n_nodes, lmesh.elems)
    return expand_node_pattern(npat, ndpn)


def dirichlet_symbolic(
    problem: FetiProblem,
    split: BoundaryInteriorSplit,
    block_size: int,
    rhs_block_size: Optional[int] = None,
    kpat: Optional[np.ndarray] = None,
) -> Tuple[SteppedMeta, np.ndarray]:
    """Symbolic products of the primal Schur stage, shared by the cluster.

    Returns ``(meta_ib, mask_ii)``: the stepped column metadata of the
    (n_i, n_b) right-hand side K_ib — its columns are boundary DOFs whose
    pivot is their first interior neighbour in elimination order — and the
    interior factor's block fill mask. Both feed
    :func:`repro.core.schur.make_assembler` exactly like the dual stage's
    B̃ᵀ metadata and K fill mask do.
    """
    if kpat is None:
        kpat = _local_dof_pattern(problem)
    P, B = split.interior, split.boundary
    pat_ii = kpat[P][:, P]
    pat_ib = kpat[P][:, B]
    mask_ii = block_symbolic_cholesky(block_pattern(pat_ii, block_size))
    meta_ib = build_stepped_meta(
        pat_ib, block_size=block_size,
        rhs_block_size=rhs_block_size or block_size)
    return meta_ib, mask_ii


def dirichlet_fingerprint(problem: FetiProblem,
                          split: BoundaryInteriorSplit,
                          kpat: Optional[np.ndarray] = None) -> str:
    """Content hash of the dirichlet stage's sparsity inputs, for the plan
    cache. Distinct from the dual stage's fingerprint by construction (the
    K_ib pivots are interior row indices), and the cache key additionally
    carries ``stage="dirichlet"`` (:func:`repro.core.autotune.
    plan_from_builder`). Pass the original-order DOF pattern ``kpat`` when
    the caller already holds it (the cluster preprocessor does)."""
    from repro.core.autotune import pattern_fingerprint

    if kpat is None:
        kpat = _local_dof_pattern(problem)
    pat_ib = kpat[split.interior][:, split.boundary]
    row_deg = kpat[split.interior][:, split.interior].sum(axis=1)
    return pattern_fingerprint(
        column_pivots(pat_ib), split.n_i, split.n_b,
        extra=[row_deg.astype(np.int64), split.interior])


def own_boundary_masks(problem: FetiProblem,
                       split: BoundaryInteriorSplit) -> np.ndarray:
    """(S, n_b) float mask, 1.0 where the shared boundary DOF carries NONE
    of that subdomain's multipliers (its "spurious" boundary — faces on
    the cluster's outer surface). These are the DOFs
    :func:`restrict_own_boundary` eliminates per subdomain; interior
    subdomains of large grids get an all-zero row (no correction)."""
    ndpn = problem.ndof_per_node
    Z = np.zeros((len(problem.subdomains), split.n_b))
    for i, sd in enumerate(problem.subdomains):
        own = np.zeros(sd.n, dtype=bool)
        own[sd.b_rows[: sd.m]] = True
        if ndpn > 1:
            own = np.repeat(own.reshape(-1, ndpn).any(axis=1), ndpn)
        Z[i] = (~own[split.boundary]).astype(np.float64)
    return Z


def restrict_own_boundary(Sb: jax.Array, z: jax.Array) -> jax.Array:
    """Eliminate one subdomain's spurious boundary DOFs from the shared
    union Schur complement — Schur complements compose, so

        S_own = S − (Z S)ᵀ E⁻¹ (Z S),   E = Z S Z + diag(1 − z),

    with Z = diag(z) selecting the spurious set, equals the Schur
    complement of K onto exactly this subdomain's glued DOFs, embedded in
    the shared (n_b, n_b) frame with exact zero spurious rows/columns
    (S_ss − S_ss S_ss⁻¹ S_ss ≡ 0). Everything is dense and shape-uniform:
    the per-subdomain variation enters through the VALUES of ``z``, so the
    correction batches under vmap and shards under shard_map like any
    other stack. ``z`` all-zero (nothing spurious) gives E = I and an
    exact no-op.
    """
    E = Sb * z[:, None] * z[None, :] + jnp.diag(1.0 - z)
    C = jnp.linalg.cholesky(E)
    ZS = z[:, None] * Sb
    Y = jax.scipy.linalg.cho_solve((C, True), ZS)
    return Sb - ZS.T @ Y


def make_dirichlet_assembler(
    split: BoundaryInteriorSplit,
    meta_ib: SteppedMeta,
    mask_ii: np.ndarray,
    cfg: SchurAssemblyConfig,
    index_ii: Optional[PackedBlockIndex] = None,
    shared: bool = False,
) -> Callable[..., jax.Array]:
    """Build the per-subdomain S_b assembler (jit/vmap/shard_map friendly).

    Returns ``assemble(Kd) -> S_b`` where ``Kd`` is one subdomain's
    (regularized) K permuted into ``split.dperm`` order and ``S_b`` is the
    dense (n_b, n_b) boundary Schur complement. Factorization storage and
    the TRSM/SYRK schedule follow ``cfg`` — the same knobs as the dual
    assembly, including packed interior factors.

    ``shared=True`` is the stage-graph factor dedup: the interior
    factorization is ELIDED and the assembler becomes
    ``assemble(L_ii, Kib, Kbb) -> S_b``, taking the leading (n_i, n_i)
    principal block of the DUAL stage's factor (valid whenever the dual
    rows are ordered ``split.dperm`` and the regularization only touches
    boundary DOFs — then L[:n_i, :n_i] IS the Cholesky factor of the
    unregularized K_ii). ``L_ii`` arrives dense; a packed ``cfg`` repacks
    it inside the compiled program via the assembler's storage coercion.
    """
    ni = split.n_i
    if ni == 0:
        # degenerate split (every DOF glued): S_b = K_bb, nothing to solve
        if shared:
            return lambda L_ii, Kib, Kbb: Kbb
        return lambda Kd: Kd

    packed = cfg.storage == "packed"
    if packed and index_ii is None:
        index_ii = PackedBlockIndex.from_mask(mask_ii, ni, cfg.block_size)
    assembler = make_assembler(meta_ib, cfg, mask_ii)

    if shared:

        def assemble_shared(L_ii: jax.Array, Kib: jax.Array,
                            Kbb: jax.Array) -> jax.Array:
            return Kbb - assembler(L_ii, Kib)

        return assemble_shared

    def assemble(Kd: jax.Array) -> jax.Array:
        Kii = Kd[:ni, :ni]
        Kib = Kd[:ni, ni:]
        Kbb = Kd[ni:, ni:]
        if packed:
            L = block_cholesky_packed(Kii, index_ii)
        else:
            L = block_cholesky(Kii, cfg.block_size, mask=mask_ii)
        return Kbb - assembler(L, Kib)

    return assemble


def assemble_dirichlet_schur(
    problem: FetiProblem,
    cfg: Union[SchurAssemblyConfig, None] = None,
    ordering: str = "nd",
    dtype=jnp.float64,
    regularized: bool = False,
    restrict: bool = True,
) -> Tuple[jax.Array, jax.Array, BoundaryInteriorSplit]:
    """One-shot convenience: (S_b stack, boundary B̃ᵀ stack, split).

    The standalone (non-batched-preprocessing) entry point used by tests
    and benchmarks; :func:`repro.feti.assembly.preprocess_cluster` inlines
    the same pieces into its compiled program instead. ``regularized``
    assembles from the fixing-DOF-regularized K (S_b is then SPD instead
    of SPSD); ``restrict=False`` skips the per-subdomain own-boundary
    restriction and returns the shared union Schur complement.
    """
    from repro.fem.regularization import fixing_dofs_regularization

    cfg = cfg or SchurAssemblyConfig()
    split = boundary_interior_split(problem, ordering=ordering)
    meta_ib, mask_ii = dirichlet_symbolic(
        problem, split, cfg.block_size, cfg.rhs_bs)
    assemble = make_dirichlet_assembler(split, meta_ib, mask_ii, cfg)
    dperm = split.dperm
    Kd = np.stack([
        (fixing_dofs_regularization(sd.K, sd.fixing_dofs)
         if regularized else sd.K)[dperm][:, dperm]
        for sd in problem.subdomains
    ])
    Sb = jax.jit(jax.vmap(assemble))(jnp.asarray(Kd, dtype=dtype))
    if restrict:
        Z = jnp.asarray(own_boundary_masks(problem, split), dtype=dtype)
        Sb = jax.jit(jax.vmap(restrict_own_boundary))(Sb, Z)
    Btb = jnp.asarray(
        np.stack([sd.Bt[split.boundary] for sd in problem.subdomains]),
        dtype=dtype)
    return Sb, Btb, split
