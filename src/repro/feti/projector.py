"""Natural coarse space of FETI: G = BR, the projector
P = I − G(GᵀG)⁻¹Gᵀ, and the α recovery (paper §2.1, eqs. 4–7).

``R`` is the subdomain-stacked kernel basis (S, n, k): k = 1 for scalar
heat (the normalized constant), k = 3/6 for 2D/3D elasticity (rigid-body
modes). Each subdomain contributes k columns to G, so G is
(n_lambda, S·k), GᵀG is the (S·k, S·k) block Gram matrix, and α is the
flattened (S·k,) vector of kernel coefficients.

The triangular coarse factor comes from a **QR of G** (R from ``qr(G)``
IS the Cholesky factor of GᵀG up to row signs), not from forming GᵀG and
factorizing it: squaring the condition number plus the stabilizing jitter
the squared form needed put an ≈1e-10 relative floor under the attainable
PCPG residual — exactly the elasticity convergence floor PR 4 pinned its
test grids around. With the QR factor the floor drops by orders of
magnitude and tight (1e-10) dual tolerances become reachable on larger
elasticity problems (see docs/preconditioners.md §Floor).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CoarseProblem", "build_coarse_problem", "coarse_g_e",
           "coarse_e", "coarse_e_many", "coarse_factor"]


def coarse_g_e(Bt: jax.Array, f: jax.Array, R: jax.Array,
               lambda_ids: jax.Array, n_lambda: int):
    """G = BR columns and e = Rᵀf for a stack of subdomains.

    ``R`` is (S, n, k); subdomain i contributes the k columns
    scatter(lambda_ids_i, B̃ᵢ R_i), laid out subdomain-major in the
    (n_lambda, S·k) result; ``e`` is the matching (S·k,) flat Rᵀf.
    The shared body of the single-device construction below and of the
    per-shard body in :mod:`repro.feti.sharded` (where ``Bt`` is that
    device's slice of subdomains)."""
    S, _, k = R.shape
    vals = jnp.einsum("snm,snk->smk", Bt, R)  # (S, m_max, k)
    s_idx = jnp.broadcast_to(jnp.arange(S)[:, None], lambda_ids.shape)
    G = jnp.zeros((n_lambda + 1, S, k), Bt.dtype)
    G = G.at[lambda_ids, s_idx].add(vals)[:-1].reshape(n_lambda, S * k)
    e = jnp.einsum("sn,snk->sk", f, R).reshape(S * k)
    return G, e


def coarse_e(f: jax.Array, R: jax.Array) -> jax.Array:
    """e = Rᵀf for one (S, n) load stack: the load-dependent half of
    :func:`coarse_g_e`, split out so a solver can stream new load cases
    through a cached coarse problem (G and its factor are load-free).
    Same einsum as :func:`coarse_g_e`, so the result is bit-identical."""
    S, _, k = R.shape
    return jnp.einsum("sn,snk->sk", f, R).reshape(S * k)


def coarse_e_many(F: jax.Array, R: jax.Array) -> jax.Array:
    """e = RᵀF for an (S, n, n_rhs) load-case stack → (S·k, n_rhs),
    subdomain-major rows matching G's column order."""
    S, _, k = R.shape
    return jnp.einsum("snr,snk->skr", F, R).reshape(S * k, F.shape[2])


def coarse_factor(G: jax.Array) -> jax.Array:
    """Lower-triangular factor L with L Lᵀ = GᵀG, computed as Rᵀ from the
    QR of G (never forming GᵀG — no condition-number squaring, no jitter).

    Row signs are normalized so the diagonal is positive (the genuine
    Cholesky factor). Rank safety, replacing what the old GᵀG jitter
    bought without its accuracy cost: structurally-zero columns of G (the
    inert padding subdomains of the sharded deployment) give exact zero R
    diagonals that are replaced by 1, so their α components come out
    exactly zero through both triangular solves; *numerically* dependent
    columns (a rank-deficient coarse problem) give ~eps-sized diagonals
    that are clamped to 1e-12 of the largest pivot, keeping the solve
    bounded like the old jittered Gram factor did. Fewer rows than
    columns (more kernel columns than multipliers — degenerate but legal)
    is handled by zero-row padding, which leaves GᵀG unchanged and lets
    the clamp absorb the missing rank.
    """
    n_rows, ncols = G.shape
    if n_rows < ncols:
        G = jnp.concatenate(
            [G, jnp.zeros((ncols - n_rows, ncols), G.dtype)])
    Rq = jnp.linalg.qr(G, mode="r")
    diag = jnp.diagonal(Rq)
    # rank guard with the old jitter's floor, applied ONLY to degenerate
    # pivots: healthy ones pass through bit-unchanged (so the old
    # jitter's ≈1e-10 residual floor stays gone), while zero/eps-sized
    # ones get the sqrt(1e-12·trace(GᵀG)/ncols) pivot the jittered Gram
    # factor would have had — rank-deficient coarse solves stay bounded,
    # and trailing zero (padding) columns still yield exactly-zero α
    # (their R rows/columns are exact zeros for any pivot value).
    floor2 = 1e-12 * jnp.sum(G * G) / ncols
    floor2 = jnp.where(floor2 == 0.0, 1.0, floor2)
    safe = jnp.where(
        diag * diag < floor2,
        jnp.sqrt(floor2) * jnp.where(diag < 0, -1.0, 1.0), diag)
    idx = jnp.arange(ncols)
    Rq = Rq.at[idx, idx].set(safe)
    sign = jnp.sign(jnp.diagonal(Rq))
    return (Rq * sign[:, None]).T


@dataclasses.dataclass
class CoarseProblem:
    G: jax.Array  # (n_lambda, S·k)
    GtG_chol: jax.Array  # (S·k, S·k) lower factor of GᵀG (QR-derived)
    e: jax.Array  # (S·k,) = Rᵀf, subdomain-major

    def solve_coarse(self, b: jax.Array) -> jax.Array:
        """(GᵀG)⁻¹ b via the cached Cholesky factor."""
        t = jax.scipy.linalg.solve_triangular(self.GtG_chol, b, lower=True)
        return jax.scipy.linalg.solve_triangular(
            self.GtG_chol.T, t, lower=False
        )

    # Every method below is rank-generic over trailing column axes: the
    # matmuls / triangular solves broadcast an (n_lambda, n_rhs) multiplier
    # stack or an (S·k, n_rhs) e-stack unchanged — this is PR 4's
    # matrix-valued α machinery, now load-bearing for the multi-RHS path.

    def project(self, x: jax.Array) -> jax.Array:
        """P x = x − G (GᵀG)⁻¹ Gᵀ x."""
        return x - self.G @ self.solve_coarse(self.G.T @ x)

    def lambda0(self, e: jax.Array = None) -> jax.Array:
        """Feasible start: λ⁰ = G(GᵀG)⁻¹e satisfies Gᵀλ⁰ = e.

        ``e`` overrides the cached load moment — a (S·k,) vector or an
        (S·k, n_rhs) stack of them for new load cases (see
        :func:`coarse_e` / :func:`coarse_e_many`)."""
        return self.G @ self.solve_coarse(self.e if e is None else e)

    def alpha(self, Flam_minus_d: jax.Array) -> jax.Array:
        """α = (GᵀG)⁻¹Gᵀ(Fλ − d): (S·k,), reshape to (S, k) per subdomain."""
        return self.solve_coarse(self.G.T @ Flam_minus_d)


def build_coarse_problem(Bt: jax.Array, f: jax.Array, R: jax.Array,
                         lambda_ids: jax.Array, n_lambda: int) -> CoarseProblem:
    """Assemble G = BR (R = stacked kernel bases) and e = Rᵀf.

    ``Bt`` and ``R`` must share a row (DOF) order — any consistent one
    works, since the shared permutation drops out of B̃ᵢ R_i; we pass the
    original-order B̃ᵀ and R.
    """
    G, e = coarse_g_e(Bt, f, R, lambda_ids, n_lambda)
    return CoarseProblem(G=G, GtG_chol=coarse_factor(G), e=e)
