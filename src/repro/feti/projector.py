"""Natural coarse space of FETI: G = BR, the projector
P = I − G(GᵀG)⁻¹Gᵀ, and the α recovery (paper §2.1, eqs. 4–7).

``R`` is the subdomain-stacked kernel basis (S, n, k): k = 1 for scalar
heat (the normalized constant), k = 3/6 for 2D/3D elasticity (rigid-body
modes). Each subdomain contributes k columns to G, so G is
(n_lambda, S·k), GᵀG is the (S·k, S·k) block Gram matrix, and α is the
flattened (S·k,) vector of kernel coefficients.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CoarseProblem", "build_coarse_problem", "coarse_g_e"]


def coarse_g_e(Bt: jax.Array, f: jax.Array, R: jax.Array,
               lambda_ids: jax.Array, n_lambda: int):
    """G = BR columns and e = Rᵀf for a stack of subdomains.

    ``R`` is (S, n, k); subdomain i contributes the k columns
    scatter(lambda_ids_i, B̃ᵢ R_i), laid out subdomain-major in the
    (n_lambda, S·k) result; ``e`` is the matching (S·k,) flat Rᵀf.
    The shared body of the single-device construction below and of the
    per-shard body in :mod:`repro.feti.sharded` (where ``Bt`` is that
    device's slice of subdomains)."""
    S, _, k = R.shape
    vals = jnp.einsum("snm,snk->smk", Bt, R)  # (S, m_max, k)
    s_idx = jnp.broadcast_to(jnp.arange(S)[:, None], lambda_ids.shape)
    G = jnp.zeros((n_lambda + 1, S, k), Bt.dtype)
    G = G.at[lambda_ids, s_idx].add(vals)[:-1].reshape(n_lambda, S * k)
    e = jnp.einsum("sn,snk->sk", f, R).reshape(S * k)
    return G, e


@dataclasses.dataclass
class CoarseProblem:
    G: jax.Array  # (n_lambda, S·k)
    GtG_chol: jax.Array  # (S·k, S·k) Cholesky factor of GᵀG
    e: jax.Array  # (S·k,) = Rᵀf, subdomain-major

    def solve_coarse(self, b: jax.Array) -> jax.Array:
        """(GᵀG)⁻¹ b via the cached Cholesky factor."""
        t = jax.scipy.linalg.solve_triangular(self.GtG_chol, b, lower=True)
        return jax.scipy.linalg.solve_triangular(
            self.GtG_chol.T, t, lower=False
        )

    def project(self, x: jax.Array) -> jax.Array:
        """P x = x − G (GᵀG)⁻¹ Gᵀ x."""
        return x - self.G @ self.solve_coarse(self.G.T @ x)

    def lambda0(self) -> jax.Array:
        """Feasible start: λ⁰ = G(GᵀG)⁻¹e satisfies Gᵀλ⁰ = e."""
        return self.G @ self.solve_coarse(self.e)

    def alpha(self, Flam_minus_d: jax.Array) -> jax.Array:
        """α = (GᵀG)⁻¹Gᵀ(Fλ − d): (S·k,), reshape to (S, k) per subdomain."""
        return self.solve_coarse(self.G.T @ Flam_minus_d)


def build_coarse_problem(Bt: jax.Array, f: jax.Array, R: jax.Array,
                         lambda_ids: jax.Array, n_lambda: int) -> CoarseProblem:
    """Assemble G = BR (R = stacked kernel bases) and e = Rᵀf.

    ``Bt`` and ``R`` must share a row (DOF) order — any consistent one
    works, since the shared permutation drops out of B̃ᵢ R_i; we pass the
    original-order B̃ᵀ and R.
    """
    G, e = coarse_g_e(Bt, f, R, lambda_ids, n_lambda)
    ncols = G.shape[1]
    GtG = G.T @ G
    # tiny jitter for the (rare) case of exactly-singular coarse problems
    GtG = GtG + 1e-12 * jnp.trace(GtG) / ncols * jnp.eye(ncols, dtype=Bt.dtype)
    return CoarseProblem(G=G, GtG_chol=jnp.linalg.cholesky(GtG), e=e)
