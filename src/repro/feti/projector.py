"""Natural coarse space of FETI: G = BR, the projector
P = I − G(GᵀG)⁻¹Gᵀ, and the α recovery (paper §2.1, eqs. 4–7)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CoarseProblem", "build_coarse_problem", "coarse_g_e"]


def coarse_g_e(Bt: jax.Array, f: jax.Array, r_norm: jax.Array,
               lambda_ids: jax.Array, n_lambda: int):
    """G = BR columns and e = Rᵀf for a stack of subdomains.

    R is the normalized constant kernel (one column per subdomain), so
    column i of G is scatter(lambda_ids_i, B̃ᵢ r_i) with r_i = r_norm·1.
    The shared body of the single-device construction below and of the
    per-shard body in :mod:`repro.feti.sharded` (where ``Bt`` is that
    device's slice of subdomains)."""
    S = Bt.shape[0]
    vals = jnp.einsum("snm,s->sm", Bt, r_norm)  # (S, m_max)
    s_idx = jnp.broadcast_to(jnp.arange(S)[:, None], lambda_ids.shape)
    G = jnp.zeros((n_lambda + 1, S), Bt.dtype)
    G = G.at[lambda_ids, s_idx].add(vals)[:-1]
    e = jnp.sum(f, axis=1) * r_norm
    return G, e


@dataclasses.dataclass
class CoarseProblem:
    G: jax.Array  # (n_lambda, S)
    GtG_chol: jax.Array  # (S, S) Cholesky factor of GᵀG
    e: jax.Array  # (S,) = Rᵀf

    def solve_coarse(self, b: jax.Array) -> jax.Array:
        """(GᵀG)⁻¹ b via the cached Cholesky factor."""
        t = jax.scipy.linalg.solve_triangular(self.GtG_chol, b, lower=True)
        return jax.scipy.linalg.solve_triangular(
            self.GtG_chol.T, t, lower=False
        )

    def project(self, x: jax.Array) -> jax.Array:
        """P x = x − G (GᵀG)⁻¹ Gᵀ x."""
        return x - self.G @ self.solve_coarse(self.G.T @ x)

    def lambda0(self) -> jax.Array:
        """Feasible start: λ⁰ = G(GᵀG)⁻¹e satisfies Gᵀλ⁰ = e."""
        return self.G @ self.solve_coarse(self.e)

    def alpha(self, Flam_minus_d: jax.Array) -> jax.Array:
        """α = (GᵀG)⁻¹Gᵀ(Fλ − d)."""
        return self.solve_coarse(self.G.T @ Flam_minus_d)


def build_coarse_problem(Bt: jax.Array, f: jax.Array, r_norm: jax.Array,
                         lambda_ids: jax.Array, n_lambda: int) -> CoarseProblem:
    """Assemble G = BR (R = normalized constants per subdomain) and e = Rᵀf.

    ``Bt`` may be in any consistent row (node) order — R is constant so the
    permutation drops out of Bᵀr; we pass the original-order B̃ᵀ.
    """
    S = Bt.shape[0]
    G, e = coarse_g_e(Bt, f, r_norm, lambda_ids, n_lambda)
    GtG = G.T @ G
    # tiny jitter for the (rare) case of exactly-singular coarse problems
    GtG = GtG + 1e-12 * jnp.trace(GtG) / S * jnp.eye(S, dtype=Bt.dtype)
    return CoarseProblem(G=G, GtG_chol=jnp.linalg.cholesky(GtG), e=e)
