"""FETI solver substrate (paper §2): batched per-cluster preprocessing
(factorization + sparsity-utilizing SC assembly), the dual operator in both
implicit and explicit form, the natural-coarse-space projector, PCPG, and
the end-to-end solver with amortization accounting (paper §5).

:mod:`repro.feti.sharded` distributes the whole pipeline by sharding the
subdomain axis over a ``("data",)`` device mesh; pass ``mesh=`` to
:class:`FetiSolver` / :func:`preprocess_cluster` to use it."""
from repro.feti.assembly import ClusterState, preprocess_cluster
from repro.feti.dirichlet import (
    BoundaryInteriorSplit,
    assemble_dirichlet_schur,
    boundary_interior_split,
)
from repro.feti.operator import (
    dirichlet_preconditioner,
    dual_rhs,
    explicit_dual_apply,
    implicit_dual_apply,
    lumped_preconditioner,
)
from repro.feti.pcpg import PCPGResult, pcpg
from repro.feti.projector import CoarseProblem, build_coarse_problem
from repro.feti.solver import FetiSolution, FetiSolver

__all__ = [
    "BoundaryInteriorSplit",
    "ClusterState",
    "CoarseProblem",
    "FetiSolution",
    "FetiSolver",
    "PCPGResult",
    "assemble_dirichlet_schur",
    "boundary_interior_split",
    "build_coarse_problem",
    "dirichlet_preconditioner",
    "dual_rhs",
    "preprocess_cluster",
    "explicit_dual_apply",
    "implicit_dual_apply",
    "lumped_preconditioner",
    "pcpg",
]
