"""FETI solver substrate (paper §2): batched per-cluster preprocessing
(factorization + sparsity-utilizing SC assembly as one planned stage
graph), the dual operator in both implicit and explicit form, the
natural-coarse-space projector, PCPG, and the end-to-end solver with
amortization accounting (paper §5).

The front door is :class:`FetiConfig`: one frozen dataclass carrying every
pipeline knob, accepted by :class:`FetiSolver`, :func:`preprocess_cluster`
and :func:`solve_many` as their single ``config`` argument (README
§Migrating to FetiConfig documents the old-keyword deprecation).

:mod:`repro.feti.sharded` distributes the whole pipeline by sharding the
subdomain axis over a ``("data",)`` device mesh; pass
``FetiConfig(mesh=...)`` to use it."""
from repro.core.stages import StageGraph, StageSpec
from repro.feti.assembly import ClusterState, preprocess_cluster
from repro.feti.config import FetiConfig, as_feti_config
from repro.feti.dirichlet import (
    BoundaryInteriorSplit,
    assemble_dirichlet_schur,
    boundary_interior_split,
)
from repro.feti.operator import (
    dirichlet_preconditioner,
    dirichlet_preconditioner_many,
    dual_rhs,
    dual_rhs_many,
    explicit_dual_apply,
    explicit_dual_apply_many,
    implicit_dual_apply,
    implicit_dual_apply_many,
    lumped_preconditioner,
    lumped_preconditioner_many,
)
from repro.feti.pcpg import PCPGManyResult, PCPGResult, pcpg, pcpg_many
from repro.feti.projector import CoarseProblem, build_coarse_problem
from repro.feti.solver import (
    FetiManySolution,
    FetiSolution,
    FetiSolver,
    solve_many,
)

__all__ = [
    "BoundaryInteriorSplit",
    "ClusterState",
    "CoarseProblem",
    "FetiConfig",
    "FetiManySolution",
    "FetiSolution",
    "FetiSolver",
    "PCPGManyResult",
    "PCPGResult",
    "StageGraph",
    "StageSpec",
    "as_feti_config",
    "assemble_dirichlet_schur",
    "boundary_interior_split",
    "build_coarse_problem",
    "dirichlet_preconditioner",
    "dirichlet_preconditioner_many",
    "dual_rhs",
    "dual_rhs_many",
    "explicit_dual_apply",
    "explicit_dual_apply_many",
    "implicit_dual_apply",
    "implicit_dual_apply_many",
    "lumped_preconditioner",
    "lumped_preconditioner_many",
    "pcpg",
    "pcpg_many",
    "preprocess_cluster",
    "solve_many",
]
