"""The one front-door configuration of the FETI pipeline: ``FetiConfig``.

Before the stage-graph redesign, :class:`~repro.feti.solver.FetiSolver`,
:func:`~repro.feti.assembly.preprocess_cluster` and the launchers each grew
their own sprawl of keyword arguments (``cfg``, ``explicit``, ``dirichlet``,
``ordering``, ``storage``, ``measure``, ``plan_cache``, ``mesh``, ...) that
had to be threaded in lockstep. This module collapses them into one frozen
dataclass that every entry point accepts as its single ``config`` argument:

    solver = FetiSolver(problem, FetiConfig(preconditioner="dirichlet"))
    state  = preprocess_cluster(problem, FetiConfig(schur="auto"))

Coercion sugar (NOT deprecated): ``config`` may also be

  * ``None``                  -> all defaults,
  * ``"auto"``                -> defaults with ``schur="auto"`` (autotune),
  * a ``SchurAssemblyConfig`` -> defaults with that assembly config,

so the common one-knob calls stay one-liners. The OLD keyword style
(``preprocess_cluster(prob, cfg, explicit=False, dirichlet=True)``) still
works through :func:`_coerce_config` but emits a ``DeprecationWarning``;
see README §Migrating to FetiConfig for the timeline. CI runs the suite
under ``-W error::DeprecationWarning`` to prove the repo itself is fully
on the new API.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Union

import jax.numpy as jnp

from repro.core.schur import SchurAssemblyConfig

__all__ = ["FetiConfig", "as_feti_config"]

_MODES = ("explicit", "implicit")
_PRECONDITIONERS = ("lumped", "dirichlet", "none")
_STORAGES = (None, "dense", "packed")
_SHARE = ("auto", True, False)


@dataclasses.dataclass(frozen=True)
class FetiConfig:
    """Everything the FETI pipeline is parameterized by, in one place.

    Attributes:
      schur: the Schur-assembly configuration — a concrete
        :class:`~repro.core.schur.SchurAssemblyConfig`, the string
        ``"auto"`` (the stage graph plans every assembly stage jointly via
        :class:`repro.core.stages.StageGraph`), or ``None`` for the
        default config.
      mode: ``"explicit"`` assembles the dual operators F̃ up front
        (paper eq. 12); ``"implicit"`` applies them factor-backed
        (eq. 11).
      preconditioner: ``"lumped"`` | ``"dirichlet"`` | ``"none"``.
        ``"dirichlet"`` grows the primal boundary-Schur stage S_b in the
        same stage graph.
      ordering: fill-reducing node ordering ("nd" | "rcm" | "natural").
      storage: factor storage override ("dense" | "packed"); ``None``
        defers to ``schur.storage`` or lets the planner choose.
      measure: autotuner measurement policy ("auto" | "never"), forwarded
        to the joint planner when ``schur == "auto"``.
      plan_cache: consult/populate the content-addressed plan cache.
      dtype: device dtype of the numeric stacks.
      mesh: a ``("data",)`` device mesh to shard the subdomain axis over
        (:mod:`repro.feti.sharded`); ``None`` = single-device.
      share_factor: dedupe the interior factorization between the dual
        and Dirichlet stages when the boundary/interior split aligns with
        the row ordering (see docs/stage_graph.md §Factor sharing).
        ``"auto"`` shares whenever valid (every subdomain's fixing DOFs
        lie on the boundary, so the regularization cannot perturb the
        shared interior factor); ``True`` requires it (raises if
        invalid); ``False`` keeps the two independent pipelines.
    """

    schur: Union[SchurAssemblyConfig, str, None] = None
    mode: str = "explicit"
    preconditioner: str = "lumped"
    ordering: str = "nd"
    storage: Optional[str] = None
    measure: str = "auto"
    plan_cache: bool = True
    dtype: Any = jnp.float64
    mesh: Any = None
    share_factor: Union[str, bool] = "auto"

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, "
                             f"got {self.mode!r}")
        if self.preconditioner not in _PRECONDITIONERS:
            raise ValueError(f"preconditioner must be one of "
                             f"{_PRECONDITIONERS}, got "
                             f"{self.preconditioner!r}")
        if self.storage not in _STORAGES:
            raise ValueError(f"storage must be one of {_STORAGES}, "
                             f"got {self.storage!r}")
        if isinstance(self.schur, str) and self.schur != "auto":
            raise ValueError("schur must be a SchurAssemblyConfig, 'auto' "
                             f"or None, got {self.schur!r}")
        if self.share_factor not in _SHARE:
            raise ValueError(f"share_factor must be one of {_SHARE}, "
                             f"got {self.share_factor!r}")

    # -- derived views used by the preprocessing/solver internals ---------

    @property
    def explicit(self) -> bool:
        return self.mode == "explicit"

    @property
    def dirichlet(self) -> bool:
        return self.preconditioner == "dirichlet"

    @property
    def auto(self) -> bool:
        return self.schur == "auto"

    def resolved_schur(self) -> SchurAssemblyConfig:
        """The concrete assembly config for non-autotuned runs."""
        if self.auto:
            raise ValueError("schur='auto' resolves during preprocessing")
        return self.schur if self.schur is not None else SchurAssemblyConfig()

    def replace(self, **changes) -> "FetiConfig":
        return dataclasses.replace(self, **changes)


def as_feti_config(config: Union[FetiConfig, SchurAssemblyConfig,
                                 str, None]) -> FetiConfig:
    """Coerce the supported ``config`` sugar into a :class:`FetiConfig`.

    Accepts a FetiConfig (returned as-is), a bare SchurAssemblyConfig,
    the string ``"auto"``, or ``None`` — the blessed shorthand forms, NOT
    deprecated. Anything else raises.
    """
    if config is None:
        return FetiConfig()
    if isinstance(config, FetiConfig):
        return config
    if isinstance(config, SchurAssemblyConfig) or config == "auto":
        return FetiConfig(schur=config)
    raise TypeError("config must be a FetiConfig, a SchurAssemblyConfig, "
                    f"'auto' or None, got {type(config).__name__}")


# old keyword -> (FetiConfig field, value transform)
_KWARG_MAP = {
    "cfg": ("schur", lambda v: v),
    "explicit": ("mode", lambda v: "explicit" if v else "implicit"),
    "mode": ("mode", lambda v: v),
    "dirichlet": ("preconditioner",
                  lambda v: "dirichlet" if v else "lumped"),
    "preconditioner": ("preconditioner", lambda v: v),
    "ordering": ("ordering", lambda v: v),
    "storage": ("storage", lambda v: v),
    "measure": ("measure", lambda v: v),
    "plan_cache": ("plan_cache", lambda v: v),
    "dtype": ("dtype", lambda v: v),
    "mesh": ("mesh", lambda v: v),
}


def _coerce_config(config, deprecated: dict, caller: str) -> FetiConfig:
    """Fold pre-FetiConfig keyword arguments into a FetiConfig.

    ``deprecated`` is the ``**kwargs`` dict of an entry point's legacy
    keywords. Non-empty triggers ONE DeprecationWarning naming the caller
    and the replacement fields; unknown keywords raise TypeError (same
    contract a real signature would enforce).
    """
    fc = as_feti_config(config)
    if not deprecated:
        return fc
    unknown = sorted(set(deprecated) - set(_KWARG_MAP))
    if unknown:
        raise TypeError(f"{caller}() got unexpected keyword argument(s) "
                        f"{', '.join(map(repr, unknown))}")
    changes = {}
    for k, v in deprecated.items():
        field, conv = _KWARG_MAP[k]
        changes[field] = conv(v)
    warnings.warn(
        f"{caller}({', '.join(sorted(deprecated))}=...) keyword arguments "
        f"are deprecated; pass FetiConfig({', '.join(sorted(set(changes)))}"
        f"=...) instead (removal: two releases after 2026-08). "
        "See README §Migrating to FetiConfig.",
        DeprecationWarning, stacklevel=3)
    return fc.replace(**changes)
