"""Cluster preprocessing: numerical factorization + explicit SC assembly,
batched over the subdomains of a cluster (paper §2.2 "preprocessing").

All subdomains of the structured decomposition share one local topology, so
they share the fill-reducing permutation, the symbolic block fill mask and
the (envelope) stepped metadata — the whole cluster preprocesses in ONE
compiled XLA program with a leading subdomain axis. This replaces the
paper's 16-CUDA-streams subdomain loop with the TPU-idiomatic batched form.

Since the stage-graph redesign the preprocessor is organized around
:class:`repro.core.stages.StageGraph`: every Schur assembly stage — the
dual operator F̃ = (L⁻¹B̃ᵀ)ᵀ(L⁻¹B̃ᵀ) and (with the Dirichlet
preconditioner) the primal boundary S_b = K_bb − K_bi K_ii⁻¹ K_ib — is
declared as a :class:`~repro.core.stages.StageSpec` and planned JOINTLY
under one cache key, then executed by one compiled prep. When the
boundary/interior split aligns with the row ordering the graph dedupes the
interior factorization: the dual rows are reordered ``split.dperm`` so the
dual factor's leading (n_i, n_i) principal block IS the Cholesky factor of
the unregularized K_ii, and the Dirichlet stage reuses it instead of
factorizing its own copy (docs/stage_graph.md §Factor sharing).

Pass ``FetiConfig(mesh=...)`` (a ``("data",)`` mesh, see
:func:`repro.launch.mesh.make_feti_mesh`) to shard the subdomain axis over
devices — the multi-node story. Preprocessing then relabels local
multipliers into each subdomain's stepped column order host-side (the
``col_perm=None`` assembler path), pads the cluster to a multiple of the
mesh size, and factorizes + assembles under ``shard_map`` so every device
owns its slice of subdomains end-to-end; :mod:`repro.feti.sharded`
documents the scheme. ``mesh=None`` keeps the single-device behavior
bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SchurAssemblyConfig,
    build_stepped_meta,
    make_assembler,
    shared_envelope,
)
from repro.core.autotune import Plan, pattern_fingerprint
from repro.core.stages import GraphPlan, StageGraph, StageSpec
from repro.core.stepped import SteppedMeta
from repro.fem.decomposition import FetiProblem
from repro.fem.meshgen import structured_mesh
from repro.fem.regularization import fixing_dofs_regularization
from repro.feti import dirichlet as dirlib
from repro.feti import sharded as shlib
from repro.feti.config import FetiConfig, _coerce_config
from repro.sparse import (
    block_pattern,
    block_symbolic_cholesky,
    matrix_pattern_from_elems,
    node_ordering,
)
from repro.sparse.cholesky import block_cholesky
from repro.sparse.packed import (
    PackedBlockIndex,
    PackedBlocks,
    block_cholesky_packed,
)

__all__ = ["ClusterState", "preprocess_cluster", "batched_assemble",
           "expand_node_perm", "expand_node_pattern"]


def expand_node_perm(node_perm: np.ndarray, ndpn: int) -> np.ndarray:
    """Expand a node permutation to node-blocked DOFs (identity for
    ndpn=1): each node's ndpn components move together, staying adjacent."""
    if ndpn == 1:
        return node_perm
    return (node_perm[:, None] * ndpn
            + np.arange(ndpn, dtype=node_perm.dtype)).reshape(-1)


def expand_node_pattern(npat: np.ndarray, ndpn: int) -> np.ndarray:
    """Expand a node adjacency pattern to node-blocked DOFs: every entry
    becomes a dense (ndpn, ndpn) block (identity for ndpn=1). The one
    definition shared by the preprocessor, the dry-run planner and the
    benchmarks, so their symbolic layouts can never diverge."""
    if ndpn == 1:
        return npat
    return np.kron(npat, np.ones((ndpn, ndpn), dtype=bool))


@dataclasses.dataclass
class ClusterState:
    """Everything the solution phase needs, stacked over subdomains.

    Stage outputs are keyed by stage name: ``outputs()["dual"]`` is the
    explicit SC stack ``F``, ``outputs()["dirichlet"]`` the boundary-Schur
    stack ``Sb``; ``stages`` carries each stage's resolved config,
    metadata and fill mask (:class:`repro.core.stages.ResolvedStage`) and
    ``graph_plan`` the joint autotuner result when ``schur="auto"``.

    When ``mesh`` is set, the subdomain-stacked device arrays are padded to
    a multiple of the mesh size, sharded over its ``data`` axis, and hold
    *relabeled* multiplier columns (each subdomain's stepped order — see
    :mod:`repro.feti.sharded`); ``lambda_ids`` is relabeled consistently so
    λ-space semantics are unchanged.
    """

    problem: FetiProblem
    cfg: SchurAssemblyConfig
    plan: Optional[Plan]  # autotuner plan when cfg was "auto", else None
    env: SteppedMeta  # shared stepped envelope (identity column perm)
    block_mask: np.ndarray  # factor block fill mask (shared)
    node_perm: np.ndarray  # fill-reducing row permutation (shared); equals
    #                        split.dperm when the interior factor is shared
    index: PackedBlockIndex  # packed block layout derived from block_mask
    # device arrays, leading axis = subdomain:
    # (S, n, n) Cholesky factors of permuted K_reg, or the packed
    # (S, n_blocks, bs, bs) stack when cfg.storage == "packed"
    L: Union[jax.Array, PackedBlocks]
    Btp: jax.Array  # (S, n, m_max) row-permuted B̃ᵀ (factor order)
    K: PackedBlocks  # packed permuted unregularized K (lumped
    #                  preconditioner); no dense (S, n, n) K is kept
    F: Optional[jax.Array]  # (S, m_max, m_max) explicit SC, or None (implicit)
    f: jax.Array  # (S, n) loads (original node order)
    fp: jax.Array  # (S, n) loads (factor order)
    lambda_ids: jax.Array  # (S, m_max) global multiplier ids (pad=n_lambda)
    col_perm: jax.Array  # (S_real, m_max) stepped column perm per subdomain
    inv_col_perm: jax.Array  # (S_real, m_max)
    R: jax.Array  # (S, n, k) orthonormal kernel bases, original DOF order
    #              (k = 1 heat constant; 3/6 elasticity rigid-body modes)
    mesh: Optional[jax.sharding.Mesh] = None  # set => stacks sharded over it
    n_real: Optional[int] = None  # subdomain count before mesh padding
    relabeled: bool = False  # multiplier columns in stepped (relabeled) order
    # the compiled preprocessor, for the multi-step regime: new values,
    # same pattern, zero recompiles. Signature depends on the stage set:
    #   (Kp, Btp) -> (L, F)                          dual only
    #   (Kp, Btp, Kd, Zb) -> (L, F, Sb)              + dirichlet
    #   (Kp, Btp, Kbb, Zb) -> (L, F, Sb)             + dirichlet, shared
    #                                                  interior factor
    prep: Optional[Callable] = None
    # ---- Dirichlet preconditioner stage (preconditioner="dirichlet") ----
    split: Optional[dirlib.BoundaryInteriorSplit] = None
    Sb: Optional[jax.Array] = None  # (S, n_b, n_b) primal boundary SCs
    Btb: Optional[jax.Array] = None  # (S, n_b, m_max) boundary rows of B̃ᵀ
    dirichlet_cfg: Optional[SchurAssemblyConfig] = None
    dirichlet_plan: Optional[Plan] = None  # when cfg was "auto", else None
    dirichlet_env: Optional[SteppedMeta] = None  # K_ib stepped metadata
    dirichlet_mask: Optional[np.ndarray] = None  # interior block fill mask
    # ---- stage graph (redesign) ----
    stages: Optional[dict] = None  # stage name -> ResolvedStage
    graph_plan: Optional[GraphPlan] = None  # joint plan when "auto"
    shared_factor: bool = False  # dirichlet reuses the dual interior factor

    @property
    def n_lambda(self) -> int:
        return self.problem.n_lambda

    @property
    def S(self) -> int:
        """Stacked subdomain count (including any mesh padding)."""
        L = self.L
        return (L.values if isinstance(L, PackedBlocks) else L).shape[0]

    @property
    def S_real(self) -> int:
        """Actual subdomain count (excluding mesh padding)."""
        return self.n_real if self.n_real is not None else self.S

    @property
    def storage(self) -> str:
        """Factor storage layout actually held ("dense" | "packed")."""
        return "packed" if isinstance(self.L, PackedBlocks) else "dense"

    def outputs(self) -> dict:
        """Stage outputs keyed by stage name (the stage-graph view)."""
        out = {"dual": self.F}
        if self.Sb is not None:
            out["dirichlet"] = self.Sb
        return out

    def device_bytes(self) -> dict:
        """Device bytes of the persistent solution-phase stacks.

        ``K`` is always packed; ``L`` is packed or dense per
        ``cfg.storage``; ``dense_L``/``dense_K`` report what the dense
        (S, n, n) stacks would cost — the packed-vs-dense headline number.
        ``per_stage`` attributes the persistent bytes to their stage graph
        node (the factor + lumped K + B̃ᵀ live with the dual stage).
        """
        def nbytes(x):
            if x is None:
                return 0
            if isinstance(x, PackedBlocks):
                return x.nbytes
            return int(np.prod(x.shape)) * x.dtype.itemsize

        n = self.index.n
        dense_one = self.S * n * n * jnp.result_type(self.Btp).itemsize
        out = {
            "L": nbytes(self.L),
            "K": nbytes(self.K),
            "Btp": nbytes(self.Btp),
            "F": nbytes(self.F),
            "Sb": nbytes(self.Sb),
            "Btb": nbytes(self.Btb),
            "dense_L": dense_one,
            "dense_K": dense_one,
        }
        out["total"] = (out["L"] + out["K"] + out["Btp"] + out["F"]
                        + out["Sb"] + out["Btb"])
        per_stage = {"dual": out["L"] + out["K"] + out["Btp"] + out["F"]}
        if self.Sb is not None:
            per_stage["dirichlet"] = out["Sb"] + out["Btb"]
        out["per_stage"] = per_stage
        return out


def batched_assemble(
    L: Union[jax.Array, PackedBlocks],
    Btp: jax.Array,
    col_perm: Optional[jax.Array],
    inv_col_perm: Optional[jax.Array],
    env: SteppedMeta,
    cfg: SchurAssemblyConfig,
    block_mask: Optional[np.ndarray],
) -> jax.Array:
    """Assemble all subdomain SCs in one vmapped program.

    Per-subdomain *column* permutations (each subdomain has its own stepped
    order) are applied as batched gathers around a single envelope-metadata
    assembler. Pass ``col_perm=None`` when B̃ᵀ is already stepped — the
    §Perf path: relabel local multipliers host-side once (the column order
    is arbitrary), and the runtime permute gathers (which GSPMD can only
    partition by replicating) vanish entirely. The paper pays for these
    permutes on every assembly (§4.4); relabeling removes them for free.
    """
    assembler = make_assembler(env, cfg, block_mask)

    if col_perm is None:
        return jax.vmap(assembler)(L, Btp)

    def one(Ls, Bs, cp, icp):
        Bpp = jnp.take(Bs, cp, axis=1)  # stepped column order
        Fp = assembler(Ls, Bpp)  # env has identity perm
        return jnp.take(jnp.take(Fp, icp, axis=0), icp, axis=1)

    return jax.vmap(one)(L, Btp, col_perm, inv_col_perm)


def _share_valid(problem: FetiProblem,
                 split: dirlib.BoundaryInteriorSplit) -> bool:
    """The interior-factor dedup is valid iff every subdomain's fixing
    DOFs lie on the (union) boundary: the fixing-DOF regularization then
    only shifts boundary diagonal entries, so the dual factor's leading
    (n_i, n_i) principal block is the Cholesky factor of the UNREGULARIZED
    K_ii — exactly what the Dirichlet stage eliminates against."""
    bset = np.zeros(split.n, dtype=bool)
    bset[split.boundary] = True
    return all(bool(bset[sd.fixing_dofs].all())
               for sd in problem.subdomains)


def make_cluster_preprocessor(problem: FetiProblem, config=None,
                              **deprecated):
    """Build the COMPILED preprocessing function for one decomposition.

    ``config`` is a :class:`~repro.feti.config.FetiConfig` (or its
    coercion sugar: a bare ``SchurAssemblyConfig``, ``"auto"``, ``None``).
    Pre-FetiConfig keyword arguments still work via ``**deprecated`` but
    emit a ``DeprecationWarning``.

    Returns (static, prep) where ``prep`` is jitted once per sparsity
    pattern — the paper's symbolic/numeric split: multi-step simulations
    recall ``prep`` with new values at zero recompiles. ``static`` carries
    the host-side symbolic products, including the resolved per-stage
    configs and (if autotuned) the joint :class:`GraphPlan`.

    Every assembly stage is declared as a :class:`StageSpec` and the set
    is planned as ONE :class:`StageGraph` when ``schur == "auto"`` — a
    single joint cache entry covers the dual operator AND the Dirichlet
    boundary stage. When the factor-sharing conditions hold
    (:func:`_share_valid`; ``share_factor`` in FetiConfig) the dual rows
    are ordered ``split.dperm`` and the Dirichlet stage reuses the dual
    factor's leading principal block instead of factorizing K_ii.

    With ``mesh`` set, ``prep`` expects subdomain-sharded stacks whose
    multiplier columns are already relabeled into each subdomain's stepped
    order (:func:`repro.feti.sharded.relabel_columns`) and runs
    factorization + the ``col_perm=None`` assembler under ``shard_map`` —
    every device processes exactly its slice of subdomains, no exchange.
    """
    fc = _coerce_config(config, deprecated, "make_cluster_preprocessor")
    explicit, dirichlet = fc.explicit, fc.dirichlet
    ordering, storage, mesh = fc.ordering, fc.storage, fc.mesh
    cfg = fc.schur if fc.schur is not None else SchurAssemblyConfig()

    subs = problem.subdomains
    S = len(subs)
    n = subs[0].n
    ndpn = problem.ndof_per_node
    n_nodes = n // ndpn
    m_max = problem.m_max
    node_shape = tuple(e + 1 for e in problem.elems_per_sub)

    # ---- symbolic phase (host, shared by all subdomains) ----
    nperm = node_ordering(node_shape, ordering)
    lmesh = structured_mesh(problem.elems_per_sub)
    npat0 = matrix_pattern_from_elems(n_nodes, lmesh.elems)
    kpat0 = expand_node_pattern(npat0, ndpn)  # original DOF order
    # vector problems: node-blocked DOFs stay adjacent under the expanded
    # permutation, and the DOF pattern is the node pattern with every
    # entry blown up to an (ndpn, ndpn) block — the natural stress case
    # for the block-sparse packed factor layout
    fill_perm = expand_node_perm(nperm, ndpn)

    # ---- Dirichlet stage symbolic phase + factor-sharing decision ----
    # the ONE boundary/interior split: computed here, threaded into every
    # dirlib consumer (dof_perm/kpat passed down so nothing is rebuilt)
    split = None
    share = False
    if dirichlet:
        split = dirlib.boundary_interior_split(problem, ordering=ordering,
                                               dof_perm=fill_perm)
        if fc.share_factor is not False and split.n_i > 0:
            ok = _share_valid(problem, split)
            if fc.share_factor is True and not ok:
                raise ValueError(
                    "share_factor=True, but some subdomain's fixing DOFs "
                    "are interior — the regularization would perturb the "
                    "shared interior factor. Use share_factor='auto'.")
            share = ok

    # factor row order: the boundary/interior layout when sharing (the
    # interior keeps its fill-reducing elimination order, so the leading
    # principal block of L is the interior factor), the plain
    # fill-reducing order otherwise
    node_perm = split.dperm if share else fill_perm
    kpat = kpat0[node_perm][:, node_perm]
    patterns = [sd.Bt[node_perm] != 0 for sd in subs]

    # builders used both by the joint planner (scoring candidate block
    # sizes) and below to materialize the symbolic products for the final
    # configs; memoized so the winning size isn't analyzed twice
    _built: dict = {}

    def _symbolic(bs: int, rbs: int):
        key = (bs, rbs)
        if key not in _built:
            # regularization only touches the diagonal: pattern unchanged
            mask = block_symbolic_cholesky(block_pattern(kpat, bs))
            metas = [
                build_stepped_meta(p, block_size=bs, rhs_block_size=rbs)
                for p in patterns
            ]
            _built[key] = (metas, shared_envelope(metas), mask)
        return _built[key]

    _dbuilt: dict = {}

    def _dsymbolic(bs: int, rbs: int):
        key = (bs, rbs)
        if key not in _dbuilt:
            _dbuilt[key] = dirlib.dirichlet_symbolic(
                problem, split, bs, rbs, kpat=kpat0)
        return _dbuilt[key]

    # ---- the stage graph: every assembly stage, planned as one unit ----
    from repro.core import column_pivots

    piv = np.stack([column_pivots(p) for p in patterns])
    dtype_bytes = np.dtype(fc.dtype).itemsize
    specs = [StageSpec(
        name="dual",
        builder=lambda bs, rbs: _symbolic(bs, rbs)[1:],
        fingerprint=pattern_fingerprint(
            piv, n, m_max,
            extra=[kpat.sum(axis=1).astype(np.int64), node_perm]),
        n=n, storage=storage, dtype_bytes=dtype_bytes,
        # without explicit assembly only the factorization block size
        # matters — don't burn timed assembly micro-runs on it
        measure=None if explicit else "never",
    )]
    if dirichlet and split.n_i > 0:
        specs.append(StageSpec(
            name="dirichlet",
            builder=_dsymbolic,
            fingerprint=dirlib.dirichlet_fingerprint(problem, split,
                                                     kpat=kpat0),
            n=split.n_i, storage=storage, dtype_bytes=dtype_bytes,
            share_factor_of="dual" if share else None,
        ))
    graph = StageGraph(specs)

    plan = d_plan = gplan = None
    if fc.auto:
        gplan = graph.plan(measure=fc.measure, cache=fc.plan_cache)
        plan = gplan["dual"]
        cfg = plan.cfg
        d_plan = gplan.plans.get("dirichlet")
    elif storage is not None and storage != cfg.storage:
        cfg = dataclasses.replace(cfg, storage=storage)
    d_cfg = None
    if dirichlet:
        d_cfg = d_plan.cfg if d_plan is not None else cfg

    cfgs = {"dual": cfg}
    if "dirichlet" in graph.by_name:
        cfgs["dirichlet"] = d_cfg
    resolved = graph.resolve(cfgs, plans=gplan.plans if gplan else None)

    env, block_mask = resolved["dual"].meta, resolved["dual"].mask
    metas = _built[(cfg.block_size, cfg.rhs_bs)][0]
    index = PackedBlockIndex.from_mask(block_mask, n, cfg.block_size)
    meta_ib = mask_ii = d_assemble = None
    if dirichlet:
        if "dirichlet" in resolved:
            meta_ib = resolved["dirichlet"].meta
            mask_ii = resolved["dirichlet"].mask
        d_assemble = dirlib.make_dirichlet_assembler(
            split, meta_ib, mask_ii, d_cfg, shared=share)
    col_perms = np.empty((S, m_max), dtype=np.int64)
    inv_col_perms = np.empty((S, m_max), dtype=np.int64)
    for i, me in enumerate(metas):
        col_perms[i] = me.perm
        inv_col_perms[i] = me.inv_perm

    cp = jnp.asarray(col_perms)
    icp = jnp.asarray(inv_col_perms)
    packed = cfg.storage == "packed"

    def _factorize(Kp_l):
        """Batched numerical factorization in the configured storage."""
        if packed:
            return jax.vmap(lambda A: block_cholesky_packed(A, index))(Kp_l)
        return jax.vmap(
            lambda A: block_cholesky(A, cfg.block_size, mask=block_mask)
        )(Kp_l)

    ni = split.n_i if split is not None else 0

    def _interior_factor(L):
        """Leading (n_i, n_i) principal block of the dual factor stack —
        the shared interior factor. A packed factor densifies transiently
        inside the compiled program (the slice itself never persists)."""
        Ld = L.unpack() if isinstance(L, PackedBlocks) else L
        return Ld[:, :ni, :ni]

    def _dirichlet_stage(L, Kp_l, *dir_l):
        """The boundary-Schur node of the graph, shared by the local and
        shard_map preps. ``dir_l`` is (Kbb, Zb) when the interior factor
        is shared — K_ib is the dual factor input's off-diagonal slice,
        unperturbed by the boundary-diagonal regularization — and
        (Kd, Zb) otherwise."""
        if share:
            Kbb_l, Zb_l = dir_l
            Sb = jax.vmap(d_assemble)(
                _interior_factor(L), Kp_l[:, :ni, ni:], Kbb_l)
        else:
            Kd_l, Zb_l = dir_l
            Sb = jax.vmap(d_assemble)(Kd_l)
        return jax.vmap(dirlib.restrict_own_boundary)(Sb, Zb_l)

    if mesh is None:

        if dirichlet:

            def prep(Kp_stack, Btp_stack, *dir_stacks):
                L = _factorize(Kp_stack)
                F = (batched_assemble(L, Btp_stack, cp, icp, env, cfg,
                                      block_mask) if explicit else None)
                return L, F, _dirichlet_stage(L, Kp_stack, *dir_stacks)

        else:

            def prep(Kp_stack, Btp_stack):
                L = _factorize(Kp_stack)
                if not explicit:
                    return L, None
                F = batched_assemble(L, Btp_stack, cp, icp, env, cfg,
                                     block_mask)
                return L, F

    else:
        from jax.sharding import PartitionSpec as P

        def _local(Kp_l, Btp_l, *dir_l):
            outs = [_factorize(Kp_l)]
            if explicit:
                # columns were relabeled host-side: col_perm=None fast path
                outs.append(batched_assemble(outs[0], Btp_l, None, None,
                                             env, cfg, block_mask))
            if dirichlet:
                outs.append(_dirichlet_stage(outs[0], Kp_l, *dir_l))
            return tuple(outs)

        n_in = 4 if dirichlet else 2
        n_out = 1 + int(explicit) + int(dirichlet)

        def prep(Kp_stack, Btp_stack, *dir_stacks):
            outs = shlib.shard_map(
                _local, mesh=mesh,
                in_specs=(P(shlib.AXIS),) * n_in,
                out_specs=(P(shlib.AXIS),) * n_out,
            )(Kp_stack, Btp_stack, *dir_stacks)
            it = iter(outs)
            L = next(it)
            F = next(it) if explicit else None
            if dirichlet:
                return L, F, next(it)
            return L, F

    static = dict(node_perm=node_perm, block_mask=block_mask, env=env,
                  col_perm=cp, inv_col_perm=icp, cfg=cfg, plan=plan,
                  index=index, split=split, dirichlet_cfg=d_cfg,
                  dirichlet_plan=d_plan, dirichlet_env=meta_ib,
                  dirichlet_mask=mask_ii, graph=graph, graph_plan=gplan,
                  stages=resolved, share=share)
    return static, jax.jit(prep)


def preprocess_cluster(problem: FetiProblem, config=None,
                       **deprecated) -> ClusterState:
    """Paper §2.2 'preprocessing': factorize every K_i and (if explicit)
    assemble every F̃ᵢ with the sparsity-utilizing pipeline.

    ``config`` is a :class:`~repro.feti.config.FetiConfig`, or one of its
    shorthand forms: a bare ``SchurAssemblyConfig``, the string ``"auto"``
    (the stage graph plans every assembly stage jointly — the chosen plans
    are available as ``ClusterState.graph_plan`` and the resolved per-stage
    configs as ``ClusterState.stages``), or ``None`` for defaults.
    Pre-FetiConfig keyword arguments (``cfg=``, ``explicit=``,
    ``dirichlet=``, ...) still work but emit a ``DeprecationWarning``.

    ``FetiConfig.storage`` overrides the factor storage layout: "packed"
    keeps every Cholesky factor as a
    :class:`~repro.sparse.packed.PackedBlocks` stack in the symbolic
    fill-mask layout (O(S·nnz_blocks) device memory), "dense" keeps
    (S, n, n) stacks. ``None`` defers to the assembly config (or lets the
    planner choose). The unregularized K kept for the lumped
    preconditioner is ALWAYS packed — no dense (S, n, n) K survives
    preprocessing in either mode.

    ``preconditioner="dirichlet"`` additionally assembles (inside the same
    compiled program) the per-subdomain primal boundary Schur complements
    S_b = K_bb − K_bi K_ii⁻¹ K_ib (:mod:`repro.feti.dirichlet`); the state
    then carries ``Sb``, the boundary-row B̃ᵀ slice ``Btb``, the split and
    the stage's own resolved config/plan. When the factor-sharing
    conditions hold (``ClusterState.shared_factor``) the stage reuses the
    dual factor's interior principal block and the preprocessor streams
    only the (S, n_b, n_b) unregularized K_bb instead of a full (S, n, n)
    copy of K.

    Pass ``FetiConfig(mesh=...)`` (``("data",)`` axis,
    :func:`repro.launch.mesh.make_feti_mesh`) to shard the subdomain axis
    over devices: multipliers are relabeled to stepped column order
    host-side, the cluster is padded to a multiple of the mesh size with
    inert identity subdomains, and all stacks land sharded. ``mesh=None``
    is bit-for-bit the single-device behavior.
    """
    fc = _coerce_config(config, deprecated, "preprocess_cluster")
    dirichlet, mesh, dtype = fc.dirichlet, fc.mesh, fc.dtype
    subs = problem.subdomains
    S = len(subs)
    static, prep = make_cluster_preprocessor(problem, fc)
    cfg = static["cfg"]  # resolved when "auto"/storage override was passed
    node_perm = static["node_perm"]
    index: PackedBlockIndex = static["index"]
    split = static["split"]
    share = static["share"]

    Kreg = np.stack(
        [fixing_dofs_regularization(sd.K, sd.fixing_dofs) for sd in subs]
    )
    Kp = Kreg[:, node_perm][:, :, node_perm]
    Btp = np.stack([sd.Bt[node_perm] for sd in subs])
    K_stack = np.stack([sd.K for sd in subs])  # unregularized, shared below
    Kd = Btb = Zb = None
    if dirichlet:
        # the dirichlet stage eliminates against the UNREGULARIZED K:
        # K_ii is SPD outright (boundary nonempty pins the kernel) and the
        # fixing-DOF diagonal shift would perturb S_b on boundary entries
        Btb = np.stack([sd.Bt[split.boundary] for sd in subs])
        Zb = dirlib.own_boundary_masks(problem, split)
        if share:
            # shared interior factor: only K_bb is streamed — K_ii and
            # K_ib already enter through the dual stage's (regularized) K,
            # whose interior rows the regularization cannot touch
            bnd = split.boundary
            Kd = K_stack[:, bnd][:, :, bnd]
        else:
            dperm = split.dperm
            Kd = K_stack[:, dperm][:, :, dperm]
    # the lumped preconditioner's K: unregularized, permuted like the
    # factor so it shares Btp — packed host-side into the fill-mask layout
    K_perm = K_stack[:, node_perm][:, :, node_perm]
    f = np.stack([sd.f for sd in subs])
    lam = np.stack([sd.lambda_ids for sd in subs])

    if mesh is None:
        S_pad = S

        def to_dev(x, dt=dtype):
            return jnp.asarray(x, dtype=dt)

    else:
        # relabel multiplier columns into each subdomain's stepped order
        # (arbitrary by construction) so the assembler and dual operator
        # run permute-free, then pad to a mesh-size multiple with inert
        # identity subdomains glued to nothing (ids -> the dummy slot)
        cp_np = np.asarray(static["col_perm"])
        Btp = shlib.relabel_columns(Btp, cp_np)
        lam = shlib.relabel_columns(lam, cp_np)
        S_pad = shlib.padded_count(S, mesh)
        Kp = shlib.pad_stack(Kp, S_pad, identity=True)
        Btp = shlib.pad_stack(Btp, S_pad)
        K_perm = shlib.pad_stack(K_perm, S_pad)
        f = shlib.pad_stack(f, S_pad)
        if dirichlet:
            # dummy subdomains: identity K (factorizable interior, S_b = I)
            # glued to nothing (zero Btb, zero own-boundary mask), so they
            # contribute nothing; in shared mode the streamed K_bb slice
            # is identity for the same reason
            Kd = shlib.pad_stack(Kd, S_pad, identity=True)
            Btb = shlib.pad_stack(shlib.relabel_columns(Btb, cp_np), S_pad)
            Zb = shlib.pad_stack(Zb, S_pad)
        pad_ids = np.full((S_pad - S, lam.shape[1]), problem.n_lambda,
                          lam.dtype)
        lam = np.concatenate([lam, pad_ids], axis=0)

        def to_dev(x, dt=dtype):
            return shlib.shard_stack(mesh, np.asarray(x, dtype=dt))

    R_stack = np.stack([sd.R for sd in subs])  # (S, n, k) original order
    if mesh is not None:
        R_stack = shlib.pad_stack(R_stack, S_pad)  # zero kernels for dummies

    Kp_j = to_dev(Kp)
    Btp_j = to_dev(Btp)
    Sb = Btb_j = None
    if dirichlet:
        Btb_j = to_dev(Btb)
        L, F, Sb = prep(Kp_j, Btp_j, to_dev(Kd), to_dev(Zb))
    else:
        L, F = prep(Kp_j, Btp_j)

    # pack K host-side (numpy blocks), then place/shard only the values
    K_vals = np.asarray(index.pack(jnp.asarray(K_perm, dtype=dtype)))
    K_packed = PackedBlocks(to_dev(K_vals), index)

    f_j = to_dev(f)
    fp_j = to_dev(f[:, node_perm])
    return ClusterState(
        problem=problem,
        cfg=cfg,
        plan=static["plan"],
        env=static["env"],
        block_mask=static["block_mask"],
        node_perm=node_perm,
        index=index,
        L=L,
        Btp=Btp_j,
        K=K_packed,
        F=F,
        f=f_j,
        fp=fp_j,
        lambda_ids=to_dev(lam, dt=None),
        col_perm=static["col_perm"],
        inv_col_perm=static["inv_col_perm"],
        R=to_dev(R_stack),
        mesh=mesh,
        n_real=S if mesh is not None else None,
        relabeled=mesh is not None,
        prep=prep,
        split=split,
        Sb=Sb,
        Btb=Btb_j,
        dirichlet_cfg=static["dirichlet_cfg"],
        dirichlet_plan=static["dirichlet_plan"],
        dirichlet_env=static["dirichlet_env"],
        dirichlet_mask=static["dirichlet_mask"],
        stages=static["stages"],
        graph_plan=static["graph_plan"],
        shared_factor=share,
    )
