"""Pallas TPU megakernel: fused stepped TRSM→SYRK (stage-graph tentpole).

Computes the lower block triangle of ``F = Yᵀ Y`` with ``L Y = B`` solved
*inside the same kernel*: the TRSM solution panel never round-trips HBM
between the two stages. The unfused pipeline writes Y once and re-reads it
``nc`` times (once per SYRK output-tile row); here Y lives in a VMEM
scratch that persists across grid iterations, so HBM traffic drops to
factor + B + F.

Schedule (DESIGN.md §2, fused):

  * 2-D grid over (bm × bm) output tiles, row-major — the TPU executes the
    grid **sequentially** on a core, which is the ordering guarantee the
    fusion rides on: program (c, 0) first forward-substitutes RHS stripe c
    into the persistent Y scratch (the stepped ``start_block`` skip
    applies exactly as in stepped_trsm), and every program (c, j ≤ c) then
    contracts stripes c and j straight out of VMEM. Stripe j < c was
    produced by program (j, 0), which precedes (c, j) in row-major order.
  * Upper-triangle programs (j > c) short-circuit to zero; ops.py mirrors
    the strict lower triangle, identical to the unfused stepped_syrk.
  * The k reduction of tile (c, j ≤ c) starts at ``start_block[c]``
    (pivots sorted ⇒ stripe c's pivot dominates), so the zero region above
    the steps is neither solved nor contracted.

VMEM budgeting: the persistent scratch holds the full (nc, n, bm) solution
panel plus the factor (dense (n, n), or the packed value stack in the
packed variant) — the fused kernel trades VMEM capacity for HBM traffic,
which is why the autotuner enumerates ``fused`` as a variant instead of
hard-wiring it (validation sizes fit comfortably; the measured refinement
keeps it honest at larger ones).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["stepped_trsm_syrk_pallas", "stepped_trsm_syrk_packed_pallas"]


def _acc_dtype(dtype):
    return jnp.float32 if dtype in (jnp.bfloat16, jnp.float16, jnp.float32) else dtype


def _syrk_tile(c, j, y_ref, start_ref, out_ref, *, bs: int, nb: int, bm: int):
    """Contract Y stripes c and j (both already in the VMEM scratch) into
    the (bm, bm) output tile — the SYRK half shared by both variants.

    ``c``/``j`` are the program ids, hoisted to the kernel top level: a
    ``pl.program_id`` call inside a ``pl.when`` body is not substituted by
    the interpreter on this jax version."""
    acc_t = _acc_dtype(out_ref.dtype)
    start = start_ref[c]  # pivots sorted => start_c >= start_j for j <= c

    def body(k, acc):
        rk = pl.ds(k * bs, bs)
        yc = y_ref[c, rk, :]
        yj = y_ref[j, rk, :]
        return acc + jnp.dot(yc.T, yj, preferred_element_type=acc_t)

    acc = jax.lax.fori_loop(start, nb, body, jnp.zeros((bm, bm), acc_t))
    out_ref[...] = acc.astype(out_ref.dtype)


def _fused_kernel(meta_ref, linv_ref, l_ref, b_ref, out_ref, y_ref,
                  *, bs: int, nb: int, bm: int):
    c = pl.program_id(0)
    j = pl.program_id(1)
    acc_t = _acc_dtype(out_ref.dtype)

    @pl.when(j == 0)
    def _trsm():  # solve stripe c into the persistent scratch
        start = meta_ref[c]
        y_ref[c] = jnp.zeros_like(y_ref[c])

        def outer(k, _):
            rk = pl.ds(k * bs, bs)
            acc = b_ref[rk, :].astype(acc_t)

            def inner(jj, acc):
                lkj = l_ref[rk, pl.ds(jj * bs, bs)]
                yj = y_ref[c, pl.ds(jj * bs, bs), :]
                return acc - jnp.dot(lkj, yj, preferred_element_type=acc_t)

            acc = jax.lax.fori_loop(start, k, inner, acc)
            yk = jnp.dot(linv_ref[k], acc, preferred_element_type=acc_t)
            y_ref[c, rk, :] = yk.astype(y_ref.dtype)
            return 0

        jax.lax.fori_loop(start, nb, outer, 0)

    @pl.when(j > c)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j <= c)
    def _syrk():
        _syrk_tile(c, j, y_ref, meta_ref, out_ref, bs=bs, nb=nb, bm=bm)


def _fused_packed_kernel(meta_ref, rowptr_ref, colidx_ref, linv_ref,
                         vals_ref, b_ref, out_ref, y_ref,
                         *, bs: int, nb: int, bm: int):
    c = pl.program_id(0)
    j = pl.program_id(1)
    acc_t = _acc_dtype(out_ref.dtype)

    @pl.when(j == 0)
    def _trsm():  # packed forward substitution: walk stored blocks only
        start = meta_ref[c]
        y_ref[c] = jnp.zeros_like(y_ref[c])

        def outer(k, _):
            rk = pl.ds(k * bs, bs)
            acc = b_ref[rk, :].astype(acc_t)
            t0 = rowptr_ref[k]
            t1 = rowptr_ref[k + 1] - 1  # diagonal slot is last in the row

            def inner(t, acc):
                jj = colidx_ref[t]
                yj = y_ref[c, pl.ds(jj * bs, bs), :]
                return acc - jnp.dot(vals_ref[t], yj,
                                     preferred_element_type=acc_t)

            acc = jax.lax.fori_loop(t0, t1, inner, acc)
            yk = jnp.dot(linv_ref[k], acc, preferred_element_type=acc_t)
            y_ref[c, rk, :] = yk.astype(y_ref.dtype)
            return 0

        jax.lax.fori_loop(start, nb, outer, 0)

    @pl.when(j > c)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j <= c)
    def _syrk():
        _syrk_tile(c, j, y_ref, meta_ref, out_ref, bs=bs, nb=nb, bm=bm)


@functools.partial(jax.jit, static_argnames=("bs", "bm", "interpret"))
def stepped_trsm_syrk_pallas(
    Linv_diag: jax.Array,  # (nb, bs, bs) pre-inverted diagonal blocks
    L: jax.Array,  # (n, n) lower factor (padded to bs multiples)
    B: jax.Array,  # (n, m) stepped RHS (padded to bm multiples)
    start_block: jax.Array,  # (m // bm,) int32: first factor block per stripe
    bs: int,
    bm: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused stepped TRSM→SYRK: lower block triangle of (L⁻¹B)ᵀ(L⁻¹B)."""
    n, m = B.shape
    if n % bs or m % bm:
        raise ValueError("inputs must be padded to block multiples (see ops.py)")
    nb, nc = n // bs, m // bm
    if Linv_diag.shape != (nb, bs, bs):
        raise ValueError(f"Linv_diag shape {Linv_diag.shape} != {(nb, bs, bs)}")
    if start_block.shape != (nc,):
        raise ValueError(f"start_block shape {start_block.shape} != {(nc,)}")

    kernel = functools.partial(_fused_kernel, bs=bs, nb=nb, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=(nc, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # start_block
            pl.BlockSpec((nb, bs, bs), lambda c, j: (0, 0, 0)),  # Linv_diag
            pl.BlockSpec((n, n), lambda c, j: (0, 0)),  # L (resident)
            pl.BlockSpec((n, bm), lambda c, j: (0, c)),  # B stripe c
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda c, j: (c, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), B.dtype),
        scratch_shapes=[pltpu.VMEM((nc, n, bm), B.dtype)],  # persistent Y
        compiler_params=pltpu.TPUCompilerParams(
            # the fusion depends on row-major sequential grid execution
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(start_block, Linv_diag, L, B)


@functools.partial(jax.jit, static_argnames=("bs", "bm", "interpret"))
def stepped_trsm_syrk_packed_pallas(
    Linv_diag: jax.Array,  # (nb, bs, bs) pre-inverted diagonal blocks
    values: jax.Array,  # (n_blocks, bs, bs) packed factor blocks
    rowptr: jax.Array,  # (nb + 1,) int32 CSR row pointers (diag last in row)
    colidx: jax.Array,  # (n_blocks,) int32 block-column of each slot
    B: jax.Array,  # (n, m) stepped RHS (padded to block multiples)
    start_block: jax.Array,  # (m // bm,) int32: first factor block per stripe
    bs: int,
    bm: int,
    interpret: bool = False,
) -> jax.Array:
    """Packed-factor fused TRSM→SYRK: VMEM holds the O(nnz_blocks·bs²)
    value stack plus the persistent Y panel — the biggest-capacity fused
    configuration."""
    n, m = B.shape
    if n % bs or m % bm:
        raise ValueError("inputs must be padded to block multiples (see ops.py)")
    nb, nc = n // bs, m // bm
    n_blocks = values.shape[0]
    if Linv_diag.shape != (nb, bs, bs):
        raise ValueError(f"Linv_diag shape {Linv_diag.shape} != {(nb, bs, bs)}")
    if values.shape != (n_blocks, bs, bs):
        raise ValueError(f"values shape {values.shape} != {(n_blocks, bs, bs)}")
    if rowptr.shape != (nb + 1,) or colidx.shape != (n_blocks,):
        raise ValueError("rowptr/colidx shapes do not match the block index")
    if start_block.shape != (nc,):
        raise ValueError(f"start_block shape {start_block.shape} != {(nc,)}")

    kernel = functools.partial(_fused_packed_kernel, bs=bs, nb=nb, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=(nc, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # start_block
            pl.BlockSpec(memory_space=pltpu.SMEM),  # rowptr
            pl.BlockSpec(memory_space=pltpu.SMEM),  # colidx
            pl.BlockSpec((nb, bs, bs), lambda c, j: (0, 0, 0)),  # Linv_diag
            pl.BlockSpec((n_blocks, bs, bs), lambda c, j: (0, 0, 0)),  # values
            pl.BlockSpec((n, bm), lambda c, j: (0, c)),  # B stripe c
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda c, j: (c, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), B.dtype),
        scratch_shapes=[pltpu.VMEM((nc, n, bm), B.dtype)],  # persistent Y
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(start_block, rowptr, colidx, Linv_diag, values, B)
