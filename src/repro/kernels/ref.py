"""Pure-jnp oracles for the Pallas stepped kernels.

These are deliberately the most boring possible implementations — a full
dense triangular solve and a full dense product — so every zero-skipping
trick in the kernels is checked against arithmetic that can't share its
bugs. (They coincide with the paper's §3.1 baseline algorithm.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["trsm_ref", "syrk_ref"]


def trsm_ref(L: jax.Array, B: jax.Array) -> jax.Array:
    """Y = L⁻¹ B via one dense triangular solve."""
    return jax.lax.linalg.triangular_solve(
        L, B, left_side=True, lower=True, transpose_a=False
    )


def syrk_ref(Y: jax.Array) -> jax.Array:
    """F = Yᵀ Y, full symmetric."""
    return Y.T @ Y
