"""Pallas TPU kernels for the paper's two compute hot-spots (§3): the
stepped TRSM and stepped SYRK, with jit wrappers (ops.py) and pure-jnp
oracles (ref.py). Validated with interpret=True on CPU; BlockSpec tiling
targets TPU VMEM/MXU."""
from repro.kernels.ops import invert_diag_blocks, stepped_syrk, stepped_trsm
from repro.kernels.ref import syrk_ref, trsm_ref

__all__ = [
    "invert_diag_blocks",
    "stepped_syrk",
    "stepped_trsm",
    "syrk_ref",
    "trsm_ref",
]
