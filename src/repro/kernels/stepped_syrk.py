"""Pallas TPU kernel: stepped SYRK (paper §3.3, adapted to the MXU).

Computes the lower block triangle of ``F = Yᵀ Y`` for a stepped Y. TPU
adaptation (DESIGN.md §2):

  * The *output splitting* becomes the 2-D Pallas **grid** over (bm × bm)
    output tiles; upper-triangle programs short-circuit to zero (the same
    schedule a causal-attention kernel uses to skip fully-masked blocks).
  * The *k-dimension reduction* is the dynamic lower bound of the k loop:
    tile (I, J≤I) accumulates only from input row-blocks at or below the
    pivot of column stripe I (``start_block[I]``) — the zero region above
    the pivots is never read.
  * Accumulation is in fp32 (MXU native) regardless of the storage dtype.

ops.py mirrors the strict lower blocks to the upper triangle afterwards;
the dense F̃ᵢ is consumed by symmetric GEMV in the PCPG solve phase.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["stepped_syrk_pallas"]


def _acc_dtype(dtype):
    return jnp.float32 if dtype in (jnp.bfloat16, jnp.float16, jnp.float32) else dtype


def _syrk_kernel(meta_ref, yi_ref, yj_ref, out_ref, *, bs: int, nb: int, bm: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    acc_t = _acc_dtype(out_ref.dtype)

    @pl.when(j > i)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j <= i)
    def _():
        start = meta_ref[i]  # pivots sorted => tile (i, j<=i) starts at i's pivot

        def body(k, acc):
            rk = pl.ds(k * bs, bs)
            yi = yi_ref[rk, :]
            yj = yj_ref[rk, :]
            return acc + jnp.dot(yi.T, yj, preferred_element_type=acc_t)

        acc = jax.lax.fori_loop(
            start, nb, body, jnp.zeros((bm, bm), acc_t)
        )
        out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bm", "interpret"))
def stepped_syrk_pallas(
    Y: jax.Array,  # (n, m) stepped TRSM solution (padded to block multiples)
    start_block: jax.Array,  # (m // bm,) int32 first contributing row block
    bs: int,
    bm: int,
    interpret: bool = False,
) -> jax.Array:
    n, m = Y.shape
    if n % bs or m % bm:
        raise ValueError("inputs must be padded to block multiples (see ops.py)")
    nb, nc = n // bs, m // bm
    if start_block.shape != (nc,):
        raise ValueError(f"start_block shape {start_block.shape} != {(nc,)}")

    kernel = functools.partial(_syrk_kernel, bs=bs, nb=nb, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=(nc, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # start_block
            pl.BlockSpec((n, bm), lambda i, j: (0, i)),  # Y column stripe I
            pl.BlockSpec((n, bm), lambda i, j: (0, j)),  # Y column stripe J
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), Y.dtype),
        interpret=interpret,
    )(start_block, Y, Y)
