"""jit'd wrappers around the Pallas stepped kernels.

Handles everything the kernels require to stay simple and MXU-aligned:
padding to block multiples (identity-padded factor diagonal), per-stripe
start-block metadata derived from the stepped pivots, pre-inversion of the
factor's diagonal blocks, and the mirror of SYRK's lower block triangle.

API mirrors the pure-jnp variants in repro.core so SchurAssemblyConfig can
dispatch between backends transparently.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stepped import SteppedMeta
from repro.kernels.stepped_syrk import stepped_syrk_pallas
from repro.kernels.stepped_trsm import (
    stepped_trsm_packed_pallas,
    stepped_trsm_pallas,
)
from repro.kernels.stepped_trsm_syrk import (
    stepped_trsm_syrk_packed_pallas,
    stepped_trsm_syrk_pallas,
)

__all__ = [
    "stepped_trsm",
    "stepped_trsm_packed",
    "stepped_syrk",
    "stepped_trsm_syrk",
    "invert_diag_blocks",
]


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    return jnp.pad(x, ((0, pr), (0, pc)))


def invert_diag_blocks(L: jax.Array, bs: int) -> jax.Array:
    """(nb, bs, bs) inverses of the factor's diagonal blocks (batched).

    Small-block inversion via triangular solve against the identity; cost
    nb·bs³ — negligible next to the TRSM itself, and it converts the whole
    kernel into MXU matmuls (see stepped_trsm.py docstring).
    """
    n = L.shape[0]
    nb = n // bs
    blocks = L.reshape(nb, bs, nb, bs)
    diag = jnp.stack([blocks[k, :, k, :] for k in range(nb)])
    eye = jnp.broadcast_to(jnp.eye(bs, dtype=L.dtype), (nb, bs, bs))
    return jax.lax.linalg.triangular_solve(
        diag, eye, left_side=True, lower=True
    )


def _start_blocks(meta: SteppedMeta, bm: int, bs: int, m_pad: int,
                  n_pad: int) -> np.ndarray:
    """First factor block each padded column stripe contributes from."""
    nb = n_pad // bs
    nc = m_pad // bm
    starts = np.full((nc,), nb, dtype=np.int32)
    for c in range(nc):
        c0 = c * bm
        if c0 < meta.m:
            piv = int(meta.pivots[c0])
            starts[c] = min(piv // bs, nb)
    return starts


def stepped_trsm(L: jax.Array, B: jax.Array, meta: SteppedMeta,
                 interpret: bool = False) -> jax.Array:
    """Pallas stepped TRSM with the same signature semantics as
    :func:`repro.core.trsm.trsm_rhs_split` (B already in stepped order)."""
    bs, bm = meta.block_size, meta.rhs_block_size
    n, m = meta.n, meta.m
    n_pad = -(-n // bs) * bs
    m_pad = -(-m // bm) * bm
    Lp = _pad_to(L, n_pad, n_pad)
    if n_pad > n:  # identity on the padded diagonal keeps blocks invertible
        idx = jnp.arange(n, n_pad)
        Lp = Lp.at[idx, idx].set(1.0)
    Bp = _pad_to(B, n_pad, m_pad)
    starts = jnp.asarray(_start_blocks(meta, bm, bs, m_pad, n_pad))
    Linv = invert_diag_blocks(Lp, bs)
    Y = stepped_trsm_pallas(Linv, Lp, Bp, starts, bs=bs, bm=bm,
                            interpret=interpret)
    return Y[:n, :m]


def stepped_trsm_packed(L, B: jax.Array, meta: SteppedMeta,
                        interpret: bool = False) -> jax.Array:
    """Pallas stepped TRSM against a PACKED factor (repro.sparse.packed).

    ``L`` is a :class:`~repro.sparse.packed.PackedBlocks` whose index was
    built at the same block size as ``meta``; only the stored factor blocks
    are shipped to the kernel (plus the CSR block index in SMEM), so VMEM
    holds O(nnz_blocks·bs²) instead of the padded dense factor.
    """
    from repro.sparse.packed import PackedBlocks

    if not isinstance(L, PackedBlocks):
        raise TypeError("stepped_trsm_packed expects a PackedBlocks factor, "
                        f"got {type(L).__name__}")
    index = L.index
    bs, bm = meta.block_size, meta.rhs_block_size
    n, m = meta.n, meta.m
    if (index.bs, index.n) != (bs, n):
        raise ValueError(
            f"packed index (n={index.n}, bs={index.bs}) does not match "
            f"stepped meta (n={n}, bs={bs})")
    n_pad = index.n_pad
    m_pad = -(-m // bm) * bm
    Bp = _pad_to(B, n_pad, m_pad)
    starts = jnp.asarray(_start_blocks(meta, bm, bs, m_pad, n_pad))
    # diagonal blocks are identity-padded by construction (pack_factor /
    # block_cholesky_packed), so they are always triangular-invertible
    diag = L.values[index.diag_slots]
    eye = jnp.broadcast_to(jnp.eye(bs, dtype=diag.dtype),
                           (index.nb, bs, bs))
    Linv = jax.lax.linalg.triangular_solve(diag, eye, left_side=True,
                                           lower=True)
    Y = stepped_trsm_packed_pallas(
        Linv, L.values,
        jnp.asarray(index.rowptr), jnp.asarray(index.cols),
        Bp, starts, bs=bs, bm=bm, interpret=interpret)
    return Y[:n, :m]


def _mirror_lower(Fl: jax.Array, bm: int, m_pad: int, m: int) -> jax.Array:
    """Mirror the strictly-lower block triangle (diagonal tiles are full)."""
    nc = m_pad // bm
    tile_row = jnp.repeat(jnp.arange(nc), bm)
    strict = tile_row[:, None] > tile_row[None, :]
    F = Fl + jnp.where(strict, Fl, 0).T
    return F[:m, :m]


def stepped_trsm_syrk(L, B: jax.Array, meta: SteppedMeta,
                      interpret: bool = False) -> jax.Array:
    """Fused Pallas TRSM→SYRK: F = (L⁻¹B)ᵀ(L⁻¹B) in ONE kernel, the
    solution panel staying in VMEM across the stage boundary
    (stepped_trsm_syrk.py). ``L`` is a dense factor or a
    :class:`~repro.sparse.packed.PackedBlocks`; dispatches accordingly."""
    from repro.sparse.packed import PackedBlocks

    bs, bm = meta.block_size, meta.rhs_block_size
    n, m = meta.n, meta.m
    m_pad = -(-m // bm) * bm
    if isinstance(L, PackedBlocks):
        index = L.index
        if (index.bs, index.n) != (bs, n):
            raise ValueError(
                f"packed index (n={index.n}, bs={index.bs}) does not match "
                f"stepped meta (n={n}, bs={bs})")
        n_pad = index.n_pad
        Bp = _pad_to(B, n_pad, m_pad)
        starts = jnp.asarray(_start_blocks(meta, bm, bs, m_pad, n_pad))
        diag = L.values[index.diag_slots]
        eye = jnp.broadcast_to(jnp.eye(bs, dtype=diag.dtype),
                               (index.nb, bs, bs))
        Linv = jax.lax.linalg.triangular_solve(diag, eye, left_side=True,
                                               lower=True)
        Fl = stepped_trsm_syrk_packed_pallas(
            Linv, L.values,
            jnp.asarray(index.rowptr), jnp.asarray(index.cols),
            Bp, starts, bs=bs, bm=bm, interpret=interpret)
    else:
        n_pad = -(-n // bs) * bs
        Lp = _pad_to(L, n_pad, n_pad)
        if n_pad > n:
            idx = jnp.arange(n, n_pad)
            Lp = Lp.at[idx, idx].set(1.0)
        Bp = _pad_to(B, n_pad, m_pad)
        starts = jnp.asarray(_start_blocks(meta, bm, bs, m_pad, n_pad))
        Linv = invert_diag_blocks(Lp, bs)
        Fl = stepped_trsm_syrk_pallas(Linv, Lp, Bp, starts, bs=bs, bm=bm,
                                      interpret=interpret)
    return _mirror_lower(Fl, bm, m_pad, m)


def stepped_syrk(Y: jax.Array, meta: SteppedMeta,
                 interpret: bool = False) -> jax.Array:
    """Pallas stepped SYRK: full symmetric F = YᵀY (lower computed by the
    kernel, strict-lower blocks mirrored here)."""
    bs, bm = meta.block_size, meta.rhs_block_size
    n, m = meta.n, meta.m
    n_pad = -(-n // bs) * bs
    m_pad = -(-m // bm) * bm
    Yp = _pad_to(Y, n_pad, m_pad)
    starts = jnp.asarray(_start_blocks(meta, bm, bs, m_pad, n_pad))
    Fl = stepped_syrk_pallas(Yp, starts, bs=bs, bm=bm, interpret=interpret)
    return _mirror_lower(Fl, bm, m_pad, m)
