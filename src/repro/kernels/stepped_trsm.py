"""Pallas TPU kernel: stepped TRSM (paper §3.2, adapted to the MXU).

Solves ``L Y = B`` where B is in stepped shape. TPU adaptation of the
paper's CUDA kernels (DESIGN.md §2):

  * The *RHS splitting* becomes the Pallas **grid**: one program per RHS
    column stripe, each starting its forward substitution at its own
    ``start_block`` (the stripe's highest column pivot, floored to the
    block grid) — the zero region above the pivots is never touched.
  * The per-block triangular solve is replaced by a **multiply with the
    pre-inverted diagonal block** (``Linv[k] @ acc``): row-serial forward
    substitution is VPU-hostile, while small pre-inverted blocks turn the
    whole kernel into dense MXU matmuls. (cuBLAS TRSM uses the same trick
    internally; here it is explicit.)
  * The factor-split GEMM update appears as the inner j loop over factor
    tiles with a dynamic lower bound — factor tiles left of ``start_block``
    are skipped, which is the paper's zero-block pruning at tile level.

VMEM budgeting: each program holds one (n, bm) RHS stripe, the (nb, bs, bs)
inverted diagonal blocks and the factor; pick bs/bm so the working set fits
VMEM (≈16 MB on v5e) — e.g. n=4096, bm=128, bs=128 gives a 2 MB stripe.
For factors too large for VMEM the factor stays in ANY/HBM and tiles are
streamed; validation sizes here fit directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["stepped_trsm_pallas", "stepped_trsm_packed_pallas"]


def _acc_dtype(dtype):
    return jnp.float32 if dtype in (jnp.bfloat16, jnp.float16, jnp.float32) else dtype


def _trsm_kernel(meta_ref, linv_ref, l_ref, b_ref, out_ref, *, bs: int, nb: int):
    c = pl.program_id(0)
    start = meta_ref[c]
    acc_t = _acc_dtype(out_ref.dtype)

    out_ref[...] = jnp.zeros_like(out_ref)

    def outer(k, _):
        rk = pl.ds(k * bs, bs)
        acc = b_ref[rk, :].astype(acc_t)

        def inner(j, acc):
            lkj = l_ref[rk, pl.ds(j * bs, bs)]
            yj = out_ref[pl.ds(j * bs, bs), :]
            return acc - jnp.dot(lkj, yj, preferred_element_type=acc_t)

        acc = jax.lax.fori_loop(start, k, inner, acc)
        yk = jnp.dot(linv_ref[k], acc, preferred_element_type=acc_t)
        out_ref[rk, :] = yk.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(start, nb, outer, 0)


@functools.partial(jax.jit, static_argnames=("bs", "bm", "interpret"))
def stepped_trsm_pallas(
    Linv_diag: jax.Array,  # (nb, bs, bs) pre-inverted diagonal blocks
    L: jax.Array,  # (n, n) lower factor (padded to bs multiples)
    B: jax.Array,  # (n, m) stepped RHS (padded to bm multiples)
    start_block: jax.Array,  # (m // bm,) int32: first factor block per stripe
    bs: int,
    bm: int,
    interpret: bool = False,
) -> jax.Array:
    n, m = B.shape
    if n % bs or m % bm:
        raise ValueError("inputs must be padded to block multiples (see ops.py)")
    nb, nc = n // bs, m // bm
    if Linv_diag.shape != (nb, bs, bs):
        raise ValueError(f"Linv_diag shape {Linv_diag.shape} != {(nb, bs, bs)}")
    if start_block.shape != (nc,):
        raise ValueError(f"start_block shape {start_block.shape} != {(nc,)}")

    kernel = functools.partial(_trsm_kernel, bs=bs, nb=nb)
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # start_block, whole array
            pl.BlockSpec((nb, bs, bs), lambda c: (0, 0, 0)),  # Linv_diag
            pl.BlockSpec((n, n), lambda c: (0, 0)),  # L
            pl.BlockSpec((n, bm), lambda c: (0, c)),  # B stripe
        ],
        out_specs=pl.BlockSpec((n, bm), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((n, m), B.dtype),
        interpret=interpret,
    )(start_block, Linv_diag, L, B)


def _trsm_packed_kernel(meta_ref, rowptr_ref, colidx_ref, linv_ref, vals_ref,
                        b_ref, out_ref, *, bs: int, nb: int):
    """Packed-factor stepped TRSM: the factor arrives as the packed
    (n_blocks, bs, bs) value stack plus its CSR-style (rowptr, colidx) block
    index in SMEM. The inner loop walks ONLY the stored subdiagonal blocks
    of row k (the diagonal slot is last in each row and is applied via its
    pre-inverted twin), so the paper's zero-block pruning is structural:
    absent blocks are never even addressed. Y blocks above the stripe's
    ``start`` stay zero, so stored blocks left of ``start`` contribute
    exact zeros — no masking needed."""
    c = pl.program_id(0)
    start = meta_ref[c]
    acc_t = _acc_dtype(out_ref.dtype)

    out_ref[...] = jnp.zeros_like(out_ref)

    def outer(k, _):
        rk = pl.ds(k * bs, bs)
        acc = b_ref[rk, :].astype(acc_t)
        t0 = rowptr_ref[k]
        t1 = rowptr_ref[k + 1] - 1  # last slot of the row is the diagonal

        def inner(t, acc):
            j = colidx_ref[t]
            yj = out_ref[pl.ds(j * bs, bs), :]
            return acc - jnp.dot(vals_ref[t], yj, preferred_element_type=acc_t)

        acc = jax.lax.fori_loop(t0, t1, inner, acc)
        yk = jnp.dot(linv_ref[k], acc, preferred_element_type=acc_t)
        out_ref[rk, :] = yk.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(start, nb, outer, 0)


@functools.partial(jax.jit, static_argnames=("bs", "bm", "interpret"))
def stepped_trsm_packed_pallas(
    Linv_diag: jax.Array,  # (nb, bs, bs) pre-inverted diagonal blocks
    values: jax.Array,  # (n_blocks, bs, bs) packed factor blocks
    rowptr: jax.Array,  # (nb + 1,) int32 CSR row pointers (diag last in row)
    colidx: jax.Array,  # (n_blocks,) int32 block-column of each slot
    B: jax.Array,  # (n, m) stepped RHS (padded to block multiples)
    start_block: jax.Array,  # (m // bm,) int32: first factor block per stripe
    bs: int,
    bm: int,
    interpret: bool = False,
) -> jax.Array:
    """Packed variant of :func:`stepped_trsm_pallas`: VMEM holds the
    O(nnz_blocks·bs²) value stack instead of the dense (n, n) factor — the
    capacity win that lets bigger subdomains fit on one core."""
    n, m = B.shape
    if n % bs or m % bm:
        raise ValueError("inputs must be padded to block multiples (see ops.py)")
    nb, nc = n // bs, m // bm
    n_blocks = values.shape[0]
    if Linv_diag.shape != (nb, bs, bs):
        raise ValueError(f"Linv_diag shape {Linv_diag.shape} != {(nb, bs, bs)}")
    if values.shape != (n_blocks, bs, bs):
        raise ValueError(f"values shape {values.shape} != {(n_blocks, bs, bs)}")
    if rowptr.shape != (nb + 1,) or colidx.shape != (n_blocks,):
        raise ValueError("rowptr/colidx shapes do not match the block index")
    if start_block.shape != (nc,):
        raise ValueError(f"start_block shape {start_block.shape} != {(nc,)}")

    kernel = functools.partial(_trsm_packed_kernel, bs=bs, nb=nb)
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # start_block
            pl.BlockSpec(memory_space=pltpu.SMEM),  # rowptr
            pl.BlockSpec(memory_space=pltpu.SMEM),  # colidx
            pl.BlockSpec((nb, bs, bs), lambda c: (0, 0, 0)),  # Linv_diag
            pl.BlockSpec((n_blocks, bs, bs), lambda c: (0, 0, 0)),  # values
            pl.BlockSpec((n, bm), lambda c: (0, c)),  # B stripe
        ],
        out_specs=pl.BlockSpec((n, bm), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((n, m), B.dtype),
        interpret=interpret,
    )(start_block, rowptr, colidx, Linv_diag, values, B)
