import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first
#   init. 512 placeholder host devices back the production meshes; nothing
#   here allocates real buffers (ShapeDtypeStruct in, compiled HLO out).

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell: build the real step
function (train_step / prefill / decode / FETI assembly / FETI solve-iter),
jit with production shardings, ``.lower().compile()``, then record

  * memory_analysis()  — per-device argument/temp/output bytes (fits HBM?)
  * cost_analysis()    — per-device FLOPs & bytes for §Roofline
  * collective schedule — op counts + payload bytes parsed from the
    optimized HLO (launch/roofline.py)

Meshes: single-pod (data=16, model=16) = 256 chips, and multi-pod
(pod=2, data=16, model=16) = 512 chips. Shape skips (encoder-only decode,
quadratic long_500k) follow DESIGN.md §5 and are recorded as "skipped".

Usage:
    python -m repro.launch.dryrun --arch all --shape all --mesh both \
        --out results/dryrun.jsonl
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import FetiArchConfig, get_config, list_archs
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
)
from repro.launch.analytic import lm_cell_counts
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    HW,
    collective_stats_trip_corrected,
    roofline_terms,
)
from repro.launch.shapes import SHAPES, applicable_shapes, cache_specs, input_specs
from repro.models import init_model
from repro.models.config import ModelConfig
from repro.train import (
    OptimizerConfig,
    TrainConfig,
    adamw_init,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

FETI_SHAPES = ("assembly", "solve_iter", "solve_iter_multi", "dirichlet")
BIG_PARAMS = 100e9  # >= this: bf16 moments + gradient accumulation


# --------------------------------------------------------------- helpers ----
def _sds_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _train_settings(cfg: ModelConfig, opt: bool = False) -> TrainConfig:
    n = cfg.param_count()
    big = n >= BIG_PARAMS
    return TrainConfig(
        optimizer=OptimizerConfig(
            moment_dtype="bfloat16" if big else "float32"
        ),
        remat=True,
        grad_accum=8 if big else 1,
        accum_dtype="bfloat16" if big else "float32",
        z_loss_coef=1e-4,
        attn_args=_opt_attn_args(opt),
    )


ATTN_ARGS = {"q_chunk": 1024, "kv_chunk": 512}


def _opt_attn_args(opt: bool) -> dict:
    # §Perf: skip causally-masked KV chunks entirely (≈2x prefill/train
    # attention flops) — exact, the mask envelope is static.
    return {**ATTN_ARGS, "skip_masked_blocks": True} if opt else ATTN_ARGS


def lower_lm_cell(cfg: ModelConfig, shape_name: str, mesh, opt: bool = False):
    from repro.distributed.actsharding import activation_sharding

    shape = SHAPES[shape_name]
    attn_args = _opt_attn_args(opt)
    min_seq = 4096 if opt else 0  # §Perf: don't seq-shard ring caches
    params_sds = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    # §Perf: serving (prefill + decode) drops FSDP (pure TP) when the
    # TP-sharded weights fit — per-step ZeRO weight regathers are pure
    # overhead when weights are stationary and gradients never flow
    tp = mesh.shape["model"]
    pure_tp_ok = cfg.param_count() * 2 / tp <= 4 * 2**30
    fsdp = not (opt and shape.kind in ("decode", "prefill") and pure_tp_ok)
    psh = param_shardings(mesh, params_sds, fsdp=fsdp)

    with activation_sharding(mesh):
        if shape.kind == "train":
            tcfg = _train_settings(cfg, opt)
            opt_sds = jax.eval_shape(
                lambda: adamw_init(params_sds, tcfg.optimizer)
            )
            osh = opt_state_shardings(mesh, opt_sds, psh)
            batch_sds = input_specs(cfg, shape)
            bsh = batch_shardings(mesh, batch_sds)
            step = make_train_step(cfg, tcfg)
            fn = jax.jit(step, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1))
            return fn.lower(params_sds, opt_sds, batch_sds)

        cache_sds = cache_specs(cfg, shape)
        csh = cache_shardings(mesh, cache_sds, min_seq_to_shard=min_seq)
        if shape.kind == "prefill":
            batch_sds = input_specs(cfg, shape)
            bsh = batch_shardings(mesh, batch_sds)
            step = make_prefill_step(cfg, attn_args=attn_args)
            fn = jax.jit(step, in_shardings=(psh, bsh, csh),
                         out_shardings=(None, csh), donate_argnums=(2,))
            return fn.lower(params_sds, batch_sds, cache_sds)

        # decode
        B = shape.global_batch
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        tok_sh = batch_shardings(mesh, {"t": tok})["t"]
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        step = make_decode_step(cfg, attn_args=attn_args)
        fn = jax.jit(step, in_shardings=(psh, tok_sh, csh, None),
                     out_shardings=(None, csh), donate_argnums=(2,))
        return fn.lower(params_sds, tok, cache_sds, idx)


# ----------------------------------------------------------- FETI cells ----
_FETI_SETUP_CACHE: dict = {}


def _feti_setup(fc: FetiArchConfig):
    """Static metadata for production-sized FETI cells (pattern only).
    Memoized: the 2M-node topology build is host-side-expensive and shared
    by assembly/solve_iter × both meshes."""
    key = (fc.dim, fc.sub_grid, fc.elems_per_sub, fc.block_size,
           fc.rhs_block_size, fc.trsm_variant, fc.syrk_variant, fc.problem)
    if key in _FETI_SETUP_CACHE:
        return _FETI_SETUP_CACHE[key]
    out = _feti_setup_impl(fc)
    _FETI_SETUP_CACHE[key] = out
    return out


def _feti_setup_impl(fc: FetiArchConfig):
    from repro.core import SchurAssemblyConfig, shared_envelope
    from repro.core.stepped import build_stepped_meta_from_pivots
    from repro.fem.decomposition import decompose_problem
    from repro.fem.meshgen import structured_mesh
    from repro.feti.assembly import expand_node_pattern, expand_node_perm
    from repro.sparse import (
        block_pattern,
        block_symbolic_cholesky,
        matrix_pattern_from_elems,
        nested_dissection_order,
    )

    prob = decompose_problem(fc.problem, fc.dim, fc.sub_grid,
                             fc.elems_per_sub, assemble_values=False)
    ndpn = prob.ndof_per_node
    node_shape = tuple(e + 1 for e in fc.elems_per_sub)
    n_nodes = int(np.prod(node_shape))
    n = n_nodes * ndpn
    nperm = nested_dissection_order(node_shape)
    npat = matrix_pattern_from_elems(
        n_nodes, structured_mesh(fc.elems_per_sub).elems)[nperm][:, nperm]
    # vector problems: node-blocked DOF expansion of the perm + pattern
    # (same scheme as repro.feti.assembly.make_cluster_preprocessor)
    dof_perm = expand_node_perm(nperm, ndpn)
    kpat = expand_node_pattern(npat, ndpn)
    inv_dof = np.empty_like(dof_perm)
    inv_dof[dof_perm] = np.arange(n)
    cfg = SchurAssemblyConfig(
        trsm_variant=fc.trsm_variant, syrk_variant=fc.syrk_variant,
        block_size=fc.block_size, rhs_block_size=fc.rhs_block_size,
    )
    mask = block_symbolic_cholesky(block_pattern(kpat, cfg.block_size))

    metas, cps, icps = [], [], []
    # pad the multiplier dim so the RHS column axis shards over 'model'
    # (the padded columns are structurally empty: pivot = n)
    m_pad = -(-prob.m_max // 64) * 64
    for sd in prob.subdomains:
        piv = np.full((m_pad,), n, np.int64)
        piv[: sd.m] = inv_dof[sd.b_rows[: sd.m]]
        me = build_stepped_meta_from_pivots(piv, n, cfg.block_size, cfg.rhs_bs)
        metas.append(me)
        cps.append(me.perm)
        icps.append(me.inv_perm)
    env = shared_envelope(metas)
    return prob, cfg, mask, env, np.stack(cps), np.stack(icps), n, m_pad


_FETI_DIRICHLET_CACHE: dict = {}


def _feti_dirichlet_setup(fc: FetiArchConfig):
    """Symbolic products of the dirichlet (primal boundary Schur) cell:
    the shared boundary/interior split, the K_ib stepped metadata and the
    interior fill mask — pattern-only, production-sized (memoized like
    :func:`_feti_setup`)."""
    key = (fc.dim, fc.sub_grid, fc.elems_per_sub, fc.block_size,
           fc.rhs_block_size, fc.problem)
    if key in _FETI_DIRICHLET_CACHE:
        return _FETI_DIRICHLET_CACHE[key]
    from repro.feti.dirichlet import (
        boundary_interior_split,
        dirichlet_symbolic,
        own_boundary_masks,
    )

    prob, cfg, _, _, _, _, n, _ = _feti_setup(fc)
    split = boundary_interior_split(prob)
    meta_ib, mask_ii = dirichlet_symbolic(prob, split, cfg.block_size,
                                          cfg.rhs_bs)
    Zb = own_boundary_masks(prob, split)
    out = (prob, cfg, split, meta_ib, mask_ii, Zb, n)
    _FETI_DIRICHLET_CACHE[key] = out
    return out


OPT_FETI_GRIDS = {2: (16, 32), 3: (8, 8, 8)}  # 512 subdomains each


def lower_feti_cell(fc: FetiArchConfig, shape_name: str, mesh,
                    opt: bool = False):
    from repro.feti.assembly import batched_assemble
    from repro.feti.operator import explicit_dual_apply
    from repro.sparse.cholesky import block_cholesky

    if opt:
        # §Perf: make the cluster count match the fleet (the paper's own
        # production regime: one independent subdomain stream per device)
        # and shard the subdomain axis over EVERY mesh axis — assembly
        # becomes embarrassingly parallel, collectives drop to zero.
        fc = dataclasses.replace(fc, sub_grid=OPT_FETI_GRIDS[fc.dim])
    prob, cfg, mask, env, cps, icps, n, m = _feti_setup(fc)
    S = prob.n_subdomains
    if opt and S % mesh.size == 0:
        dp = tuple(mesh.shape.keys())
    else:
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    cp_j, icp_j = jnp.asarray(cps), jnp.asarray(icps)

    if shape_name == "assembly":
        # paper §2.2 preprocessing: batched masked Cholesky + SC assembly.
        # §Perf (opt): local multipliers are relabeled host-side so B̃ᵀ
        # arrives pre-stepped — no runtime permute gathers (see
        # batched_assemble docstring).
        def assembly(K_stack, Bt_stack):
            L = jax.vmap(
                lambda A: block_cholesky(A, cfg.block_size, mask=mask)
            )(K_stack)
            F = batched_assemble(
                L, Bt_stack, None if opt else cp_j,
                None if opt else icp_j, env, cfg, mask,
            )
            return L, F

        K_sds = jax.ShapeDtypeStruct((S, n, n), jnp.float32)
        B_sds = jax.ShapeDtypeStruct((S, n, m), jnp.float32)
        rhs_ax = None if "model" in dp else "model"  # RHS columns = TP
        in_sh = (
            NamedSharding(mesh, P(dp, None, None)),
            NamedSharding(mesh, P(dp, None, rhs_ax)),
        )
        out_sh = (
            NamedSharding(mesh, P(dp, None, None)),
            NamedSharding(mesh, P(dp, None, None)),
        )
        fn = jax.jit(assembly, in_shardings=in_sh, out_shardings=out_sh)
        return fn.lower(K_sds, B_sds)

    if shape_name == "dirichlet":
        # the dirichlet preconditioner's primal boundary Schur stage:
        # batched interior factorization + K_ib-RHS TRSM/SYRK through the
        # same assembler machinery + the own-boundary restriction epilogue
        from repro.feti.dirichlet import (
            make_dirichlet_assembler,
            restrict_own_boundary,
        )

        _, _, split, meta_ib, mask_ii, _, _ = _feti_dirichlet_setup(fc)
        d_assemble = make_dirichlet_assembler(split, meta_ib, mask_ii, cfg)

        def dirichlet_stage(Kd_stack, Zb_stack):
            Sb = jax.vmap(d_assemble)(Kd_stack)
            return jax.vmap(restrict_own_boundary)(Sb, Zb_stack)

        Kd_sds = jax.ShapeDtypeStruct((S, n, n), jnp.float32)
        Zb_sds = jax.ShapeDtypeStruct((S, split.n_b), jnp.float32)
        in_sh = (
            NamedSharding(mesh, P(dp, None, None)),
            NamedSharding(mesh, P(dp, None)),
        )
        out_sh = NamedSharding(mesh, P(dp, None, None))
        fn = jax.jit(dirichlet_stage, in_shardings=in_sh,
                     out_shardings=out_sh)
        return fn.lower(Kd_sds, Zb_sds)

    # solve_iter: one explicit dual-operator application (paper eq. 12);
    # solve_iter_multi: the same application on an (n_lambda, n_rhs)
    # multiplier stack (block-PCPG, ISSUE 6) — per-subdomain GEMV -> GEMM
    nl = prob.n_lambda
    ids = np.full((S, m), nl, np.int64)
    for i, sd in enumerate(prob.subdomains):
        ids[i, : sd.lambda_ids.shape[0]] = sd.lambda_ids
    lam_ids = jnp.asarray(ids)

    F_sds = jax.ShapeDtypeStruct((S, m, m), jnp.float32)
    in_sh = (NamedSharding(mesh, P(dp, None, None)), NamedSharding(mesh, P()))
    if shape_name == "solve_iter_multi":
        from repro.feti.operator import explicit_dual_apply_many
        from repro.launch.analytic import FETI_SOLVE_N_RHS

        def solve_iter_multi(F_stack, Lam):
            return explicit_dual_apply_many(F_stack, lam_ids, nl, Lam)

        Lam_sds = jax.ShapeDtypeStruct((nl, FETI_SOLVE_N_RHS), jnp.float32)
        fn = jax.jit(solve_iter_multi, in_shardings=in_sh)
        return fn.lower(F_sds, Lam_sds)

    def solve_iter(F_stack, lam):
        return explicit_dual_apply(F_stack, lam_ids, nl, lam)

    lam_sds = jax.ShapeDtypeStruct((nl,), jnp.float32)
    fn = jax.jit(solve_iter, in_shardings=in_sh)
    return fn.lower(F_sds, lam_sds)


def feti_cell_counts(fc: FetiArchConfig, shape_name: str, chips: int):
    """Analytic counts for the FETI cells (mirrors the LM analytic model).

    Executed flops = the stepped (sparsity-utilizing) schedule's own flop
    model — the very quantity the paper optimizes; the dense §3.1 baseline
    flops are recorded in notes so the stepped speedup is visible per cell.
    """
    from repro.core import SchurAssemblyConfig, assembly_flops
    from repro.launch.analytic import CellCounts
    from repro.sparse.cholesky import block_cholesky_flops

    prob, cfg, mask, env, _, _, n, m = _feti_setup(fc)
    S = prob.n_subdomains
    fb = 4  # f32
    if shape_name == "assembly":
        stepped = assembly_flops(env, cfg)["total"]
        dense = (env.flops_trsm_dense() + env.flops_syrk_dense())
        chol = block_cholesky_flops(n, cfg.block_size, mask)
        chol_dense = block_cholesky_flops(n, cfg.block_size)
        flops_global = float(S * (stepped + chol))
        # traffic: read K, write L, stream L against the RHS stripe (factor
        # split reads each factor block once per active stripe), write Y+F
        bytes_global = float(S * (2 * n * n + 3 * n * m + m * m) * fb)
        resident = float(S * (2 * n * n + n * m + m * m) * fb)
        notes = {
            "stepped_assembly_flops": stepped,
            "dense_baseline_flops": dense,
            "stepped_speedup_vs_dense": dense / max(stepped, 1),
            "cholesky_flops_masked": chol,
            "cholesky_flops_dense": chol_dense,
        }
    elif shape_name == "dirichlet":
        _, _, split, meta_ib, mask_ii, _, _ = _feti_dirichlet_setup(fc)
        ni, nb = split.n_i, split.n_b
        stepped = assembly_flops(meta_ib, cfg)["total"]
        chol_ii = block_cholesky_flops(ni, cfg.block_size, mask_ii)
        # own-boundary restriction epilogue: dense chol of E (n_b³/3),
        # two triangular solves with n_b RHS (2·n_b³) and the rank-update
        # GEMM (2·n_b³) — all dense n_b-sized, batched
        restrict = nb ** 3 // 3 + 4 * nb ** 3
        flops_global = float(S * (stepped + chol_ii + restrict))
        # read Kd once, write S_b; the interior factor is transient
        bytes_global = float(S * (n * n + 2 * nb * nb) * fb)
        resident = float(S * nb * nb * fb)  # only S_b persists
        notes = {
            "boundary_dofs": nb,
            "interior_dofs": ni,
            "stepped_assembly_flops": stepped,
            "cholesky_ii_flops_masked": chol_ii,
            "restriction_flops": restrict,
            # stage-graph notes (docs/stage_graph.md): when the dual
            # stage orders DOFs interior-first and the fixing DOFs are
            # all boundary, the graph reuses the dual factor's leading
            # block — the K_ii factorization drops out entirely, and
            # the stage streams K_bb instead of the full permuted K
            "cholesky_ii_flops_saved_if_shared": chol_ii,
            "bytes_saved_if_shared": float(S * (n * n - nb * nb) * fb),
            # the fused TRSM→SYRK megakernel additionally skips the
            # HBM round-trip of the TRSM result panel Y = L_ii⁻¹ K_ib
            "fused_intermediate_bytes_skipped": float(S * ni * nb * fb),
        }
    else:  # solve_iter / solve_iter_multi
        from repro.launch.analytic import (
            FETI_SOLVE_N_RHS,
            feti_solve_iter_counts,
        )

        n_rhs = FETI_SOLVE_N_RHS if shape_name == "solve_iter_multi" else 1
        iter_counts = feti_solve_iter_counts(S, m, n_rhs=n_rhs, fb=fb)
        flops_global = iter_counts["flops"]
        bytes_global = iter_counts["bytes"]
        # the SC stack persists across iterations; multiplier stacks ride
        # along (tiny for any realistic n_rhs)
        resident = float(S * m * m * fb + 2 * prob.n_lambda * n_rhs * fb)
        notes = {
            "explicit_gemm_per_subdomain": 2 * m * m * n_rhs,
            **{f"solve_iter_{k}": v for k, v in iter_counts.items()},
        }
    return CellCounts(
        flops_global=flops_global,
        flops_per_dev=flops_global / chips,
        hbm_bytes_per_dev=bytes_global / chips,
        hbm_resident_per_dev=resident / chips,
        model_flops=flops_global,
        notes=notes,
    )


# --------------------------------------------------------------- driver ----
def analyze(lowered, chips: int, counts, link_bw) -> dict:
    """Compile + extract everything §Roofline needs.

    FLOP/byte numerators come from the analytic model (``counts``) — XLA's
    cost_analysis counts loop bodies once (verified; see analytic.py) and
    the CPU backend's bf16->f32 upcasts inflate memory_analysis, so both
    HLO numbers are recorded as auxiliary only. Collective payloads come
    from the compiled HLO with while-loop trip-count correction.
    """
    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax < 0.5 wraps it in a list
        cost = cost[0] if cost else {}
    coll = collective_stats_trip_corrected(compiled.as_text())
    roof = roofline_terms(
        {"flops": counts.flops_per_dev,
         "bytes accessed": counts.hbm_bytes_per_dev},
        coll, chips, counts.model_flops, link_bw,
    )
    per_dev_bytes = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    return {
        "compile_s": round(compile_s, 2),
        "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
        "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
        "out_bytes_per_dev": int(ma.output_size_in_bytes),
        "cpu_backend_peak_bytes_per_dev": int(per_dev_bytes),
        "analytic_resident_bytes_per_dev": int(counts.hbm_resident_per_dev),
        "fits_hbm": bool(counts.hbm_resident_per_dev <= HW["hbm_bytes"]),
        "hlo_cost_flops_loop_body_once": float(cost.get("flops", 0.0)),
        "hlo_cost_bytes_loop_body_once": float(
            cost.get("bytes accessed", 0.0)
        ),
        "collectives": {
            "bytes": coll.bytes_by_op,
            "count": coll.count_by_op,
        },
        "analytic": counts.notes,
        "roofline": roof.as_dict(),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             skip_masked: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    tp = mesh.shape["model"]
    link_bw = HW["dci_bw"] if multi_pod else HW["ici_bw"]
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
    }
    cfg = get_config(arch)
    opt = skip_masked  # one flag drives every §Perf optimization
    rec["optimized"] = opt
    try:
        if isinstance(cfg, FetiArchConfig):
            fc_eff = (dataclasses.replace(cfg, sub_grid=OPT_FETI_GRIDS[cfg.dim])
                      if opt else cfg)
            lowered = lower_feti_cell(cfg, shape_name, mesh, opt)
            counts = feti_cell_counts(fc_eff, shape_name, chips)
        else:
            # NOTE: moe_impl="sort" removes the 4·E·C·d dispatch flops
            # (measured: deepseek prefill compute 9.13s -> 2.57s/dev) but
            # under GSPMD the per-group expert buffer loses EP locality and
            # the expert weights get all-gathered per layer (+63s
            # collective) — net LOSS, so the optimized grid keeps GShard.
            # An EP-aware sort dispatch needs shard_map (future work);
            # §Perf cell A records the full hypothesis/refutation.
            shape = SHAPES[shape_name]
            lowered = lower_lm_cell(cfg, shape_name, mesh, opt)
            tcfg = _train_settings(cfg, opt)
            counts = lm_cell_counts(
                cfg, shape, chips=chips, tp=tp,
                grad_accum=tcfg.grad_accum, remat=tcfg.remat,
                moment_bytes=2 if tcfg.optimizer.moment_dtype == "bfloat16"
                else 4,
                accum_bytes=2 if tcfg.accum_dtype == "bfloat16" else 4,
                q_chunk=ATTN_ARGS["q_chunk"], kv_chunk=ATTN_ARGS["kv_chunk"],
                skip_masked=opt,
            )
        rec.update(analyze(lowered, chips, counts, link_bw))
        rec["status"] = "ok"
    except Exception as e:  # a failing cell is a bug; record it loudly
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def iter_cells(arch_sel: str, shape_sel: str, mesh_sel: str):
    archs = list_archs() if arch_sel == "all" else [arch_sel]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[mesh_sel]
    for arch in archs:
        cfg = get_config(arch)
        if isinstance(cfg, FetiArchConfig):
            shapes = list(FETI_SHAPES)
        else:
            shapes = applicable_shapes(cfg)
        skipped = ([] if isinstance(cfg, FetiArchConfig)
                   else [s for s in SHAPES if s not in shapes])
        if shape_sel != "all":
            shapes = [s for s in shapes if s == shape_sel]
            skipped = [s for s in skipped if s == shape_sel]
        for shape in shapes:
            for mp in meshes:
                yield arch, shape, mp, False
        for shape in skipped:
            yield arch, shape, False, True


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", choices=("single", "multi", "both"),
                   default="both")
    p.add_argument("--opt", action="store_true",
                   help="apply the §Perf optimizations (sort-MoE, causal "
                        "block skipping, ring-cache replication, fleet-"
                        "matched FETI decomposition)")
    p.add_argument("--out", default="results/dryrun.jsonl")
    args = p.parse_args(argv)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = n_err = 0
    with open(args.out, "a") as f:
        for arch, shape, mp, skip in iter_cells(args.arch, args.shape,
                                                args.mesh):
            if skip:
                cfg = get_config(arch)
                reason = ("encoder-only: no decode step"
                          if cfg.is_encoder_only
                          else "full attention: long_500k needs sub-quadratic")
                rec = {"arch": arch, "shape": shape, "mesh": "-",
                       "status": "skipped", "reason": reason}
                print(f"[dryrun] SKIP  {arch:22s} {shape:12s} ({reason})")
            else:
                t0 = time.perf_counter()
                rec = run_cell(arch, shape, mp, skip_masked=args.opt)
                dt = time.perf_counter() - t0
                if rec["status"] == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    res_gib = rec["analytic_resident_bytes_per_dev"] / 2**30
                    peak_gib = rec["cpu_backend_peak_bytes_per_dev"] / 2**30
                    useful = r["useful_ratio"]
                    if useful is not None:
                        useful = round(useful, 3)
                    print(
                        f"[dryrun] OK    {arch:22s} {shape:12s} "
                        f"{rec['mesh']:8s} {dt:6.1f}s "
                        f"res/dev={res_gib:6.2f}GiB "
                        f"cpuPeak={peak_gib:6.1f}GiB "
                        f"dom={r['dominant']:10s} "
                        f"useful={useful}"
                    )
                else:
                    n_err += 1
                    print(f"[dryrun] ERROR {arch:22s} {shape:12s} "
                          f"{rec['mesh']:8s}: {rec['error']}")
                if rec.get("traceback") and n_err <= 3:
                    print(rec["traceback"][-800:])
            rec.pop("traceback", None)
            f.write(json.dumps(rec) + "\n")
            f.flush()
    print(f"[dryrun] done: {n_ok} ok, {n_err} errors -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
