"""Render the final EXPERIMENTS tables: baseline + optimized reports and
the §Perf roofline-fraction summary.

    PYTHONPATH=src python -m repro.launch.finalize

Roofline fraction per cell = unavoidable_time / dominant_term, where
unavoidable_time = max(model-flops time, mandatory-stream time):
  * model-flops time  = MODEL_FLOPS / (chips × peak)  (compute floor)
  * mandatory stream  = weight+cache bytes that must move once per step
    (memory floor; relevant for decode)
"""
from __future__ import annotations

from repro.launch.report import dryrun_table, load, roofline_table
from repro.launch.roofline import HW


def fraction(rec) -> float:
    ro = rec["roofline"]
    model_t = ro["model_flops"] / (rec["chips"] * HW["peak_flops"])
    an = rec.get("analytic", {})
    stream = an.get("weight_stream_dev", 0.0) + an.get("cache_stream_dev", 0.0)
    floor = max(model_t, stream / HW["hbm_bw"])
    dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
    return min(floor / max(dom, 1e-30), 1.0)


def main(argv=None):
    base = load("results/dryrun.jsonl")
    opt = load("results/dryrun_optimized.jsonl")

    for name, recs in (("baseline", base), ("optimized", opt)):
        with open(f"results/report_{name}.md", "w") as f:
            n_ok = sum(r["status"] == "ok" for r in recs)
            n_skip = sum(r["status"] == "skipped" for r in recs)
            f.write(f"# Dry-run report ({name}): {n_ok} compiled cells, "
                    f"{n_skip} skips\n\n")
            f.write(dryrun_table(recs) + "\n\n")
            f.write("## Roofline (single-pod 16x16)\n\n")
            f.write(roofline_table(recs, "16x16") + "\n\n")
            f.write("## Roofline (multi-pod 2x16x16)\n\n")
            f.write(roofline_table(recs, "2x16x16") + "\n")

    bmap = {(r["arch"], r["shape"], r.get("mesh")): r for r in base
            if r["status"] == "ok"}
    omap = {(r["arch"], r["shape"], r.get("mesh")): r for r in opt
            if r["status"] == "ok"}
    print("| cell | baseline dominant | baseline fraction "
          "| optimized dominant | optimized fraction | gain on dominant |")
    print("|---|---|---|---|---|---|")
    rows_all = []
    for key in sorted(omap):
        if key not in bmap or key[2] != "16x16":
            continue
        b, o = bmap[key], omap[key]
        bd = max(b["roofline"]["compute_s"], b["roofline"]["memory_s"],
                 b["roofline"]["collective_s"])
        od = max(o["roofline"]["compute_s"], o["roofline"]["memory_s"],
                 o["roofline"]["collective_s"])
        rows_all.append((key, bd, od))
        print(f"| {key[0]} × {key[1]} | {b['roofline']['dominant']} "
              f"{bd * 1e3:.2f}ms | {fraction(b):.3f} "
              f"| {o['roofline']['dominant']} {od * 1e3:.2f}ms "
              f"| {fraction(o):.3f} | {bd / max(od, 1e-30):.2f}x |")
    gains = [bd / max(od, 1e-30) for _, bd, od in rows_all]
    import statistics

    print("\nmedian dominant-term gain across the grid: "
          f"{statistics.median(gains):.2f}x; "
          f"max: {max(gains):.2f}x")


if __name__ == "__main__":
    main()
