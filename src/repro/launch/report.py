"""Render EXPERIMENTS.md tables from results/dryrun.jsonl.

    PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    recs = [json.loads(line) for line in open(path)]
    # dedup: keep the LAST record per (arch, shape, mesh, status-kind)
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r.get("mesh", "-"))] = r
    return list(seen.values())


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | status | res GiB/dev | FLOPs/dev "
            "| coll GiB/dev | #coll | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    key = lambda x: (x["arch"], x["shape"], x.get("mesh", ""))  # noqa: E731
    for r in sorted(recs, key=key):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | - "
                        f"| SKIP: {r['reason']} | | | | | |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
            f"| {fmt_bytes(r['analytic_resident_bytes_per_dev'])} "
            f"| {ro['flops_per_dev']:.2e} "
            f"| {fmt_bytes(ro['coll_bytes_per_dev'])} "
            f"| {sum(r['collectives']['count'].values())} "
            f"| {r['compile_s']} |"
        )
    return "\n".join(rows)


def roofline_table(recs, mesh="16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s "
            "| dominant | MODEL_FLOPS | useful ratio | bottleneck note |",
            "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        ("compute"): "more MXU-efficient schedule / fewer executed flops",
        ("memory"): "raise arithmetic intensity (cache dtype, fusion, batch)",
        ("collective"): "shard to cut payloads / overlap with compute",
    }
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] != "ok" or r.get("mesh") != mesh:
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} "
            f"| {fmt_s(ro['collective_s'])} | **{ro['dominant']}** "
            f"| {ro['model_flops']:.2e} "
            f"| {ro['useful_ratio']:.3f} "
            f"| {notes[ro['dominant']]} |"
        )
    return "\n".join(rows)


def pick_hillclimb(recs) -> dict:
    """worst useful ratio / most collective-bound / paper-representative."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"
          and not r["arch"].startswith("feti")]
    worst = min(ok, key=lambda r: r["roofline"]["useful_ratio"])
    coll = max(ok, key=lambda r: (r["roofline"]["collective_s"]
                                  / max(max(r["roofline"]["compute_s"],
                                            r["roofline"]["memory_s"]), 1e-30)))
    return {
        "worst_useful": (worst["arch"], worst["shape"]),
        "most_collective": (coll["arch"], coll["shape"]),
        "paper_representative": ("feti-heat-3d", "assembly"),
    }


def main(argv=None):
    path = (argv or sys.argv[1:])[0] if (argv or sys.argv[1:]) else \
        "results/dryrun.jsonl"
    recs = load(path)
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    print(f"## Dry-run census: {n_ok} compiled cells, {n_skip} documented skips\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "2x16x16"))
    print("\n## Hillclimb picks\n")
    print(json.dumps(pick_hillclimb(recs), indent=2))


if __name__ == "__main__":
    main()
