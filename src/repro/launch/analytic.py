"""Exact analytic FLOP / HBM-byte counts per (arch × shape) cell.

Why this exists: XLA's ``cost_analysis()`` on the compiled module counts a
while-loop *body once* (verified on this backend: a 10-iteration scan
reports 1 iteration of flops), so any scanned model (all of ours — layers,
microbatches, attention chunks) is undercounted by orders of magnitude.
And the CPU backend upcasts bf16 matmuls to f32, inflating
``memory_analysis`` temp sizes with f32 weight copies a real TPU never
materializes.

So the roofline numerators are computed here — from the *same loop
structure the compiled program executes* (chunk schedules, capacity
factors, remat passes), exactly like the FETI side's assembly_flops. The
HLO artifact still supplies what only it can: compile success, the
collective schedule, and (caveated) memory bounds.

All values are EXECUTED work (remat recompute and baseline masked-chunk
attention included), not idealized-model work — MODEL_FLOPS (6·N·D) is
reported separately so the useful/executed ratio exposes the waste.
"""
from __future__ import annotations

import dataclasses
import math

from repro.models.config import ModelConfig
from repro.launch.shapes import ShapeCase

__all__ = ["CellCounts", "lm_cell_counts", "feti_solve_iter_counts",
           "FETI_SOLVE_N_RHS"]

# default multi-RHS width of the ``solve_iter_multi`` dry-run cell; also
# the middle of benchmarks/bench_feti.py's n_rhs sweep (1, 4, 16, 64)
FETI_SOLVE_N_RHS = 16


def feti_solve_iter_counts(S: int, m: int, n_rhs: int = 1,
                           fb: int = 4) -> dict:
    """Executed flops / HBM bytes of ONE explicit dual-operator
    application (paper eq. 12) on an (n_lambda, n_rhs) multiplier stack.

    The single shared multi-RHS cost model: dryrun's ``solve_iter`` /
    ``solve_iter_multi`` cells and ``FetiSolver.amortization_report`` /
    ``bench_feti``'s amortization rows all call this, so their numbers
    agree by construction (the latent ``n_rhs=1`` assumption the cells
    used to hard-code is now an explicit argument).

    Flops: one (m×m)·(m×n_rhs) GEMM per subdomain = ``2·S·m²·n_rhs`` —
    linear in n_rhs. Bytes: the (S, m, m) SC stack streams from memory
    ONCE per block application regardless of n_rhs (that is the whole
    multi-RHS amortization), plus the in/out multiplier stacks — so
    arithmetic intensity grows ≈linearly with n_rhs until the GEMM turns
    compute-bound.
    """
    if n_rhs < 1:
        raise ValueError(f"n_rhs must be >= 1, got {n_rhs}")
    flops = 2.0 * S * m * m * n_rhs
    bytes_ = float(S * (m * m + 2 * m * n_rhs) * fb)
    return {
        "flops": float(flops),
        "bytes": bytes_,
        "flops_per_rhs": float(flops / n_rhs),
        "bytes_per_rhs": bytes_ / n_rhs,
        "arithmetic_intensity": flops / bytes_,
        "n_rhs": int(n_rhs),
    }


@dataclasses.dataclass
class CellCounts:
    flops_global: float  # executed flops per step, whole fleet
    flops_per_dev: float
    hbm_bytes_per_dev: float  # HBM traffic per step per device
    hbm_resident_per_dev: float  # steady-state residency (fit check)
    model_flops: float  # 6·N_active·D (train) / 2·N_active·D (serve)
    notes: dict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _bytes_of(dtype_str: str) -> int:
    return {"bfloat16": 2, "float16": 2, "float32": 4,
            "float8_e4m3fn": 1}[dtype_str]


def _fit_chunk(chunk, total):
    chunk = min(chunk, total)
    while total % chunk:
        chunk -= 1
    return chunk


def _attn_sched_flops(cfg: ModelConfig, Sq: int, Skv: int, B: int,
                      q_chunk: int, kv_chunk: int, window: int,
                      skip_masked: bool, n_layers: int) -> float:
    """Executed score+PV flops of the chunked attention across layers.

    Mirrors models.attention.flash_attention exactly: baseline visits every
    (q_chunk, kv_chunk) pair (masked blocks still compute); with
    skip_masked only causally-live kv chunks run; a window bounds live kv
    chunks to ceil(W/ck)+1 per q chunk.
    """
    if n_layers == 0 or cfg.num_heads == 0:
        return 0.0
    cq = _fit_chunk(q_chunk, Sq)
    ck = _fit_chunk(kv_chunk, Skv)
    nq, nkv = Sq // cq, Skv // ck
    if cfg.attn_kind == "mla":
        d_qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        d_v = cfg.v_head_dim
    else:
        d_qk = d_v = cfg.head_dim
    H = cfg.num_heads
    pairs = 0
    for qi in range(nq):
        if window > 0:
            live = min(nkv, math.ceil(window / ck) + 1)
        elif skip_masked and cfg.causal and Sq > 1:
            hi = (qi + 1) * cq
            live = min((hi + ck - 1) // ck, nkv)
        else:
            live = nkv
        pairs += live
    # per (q,kv) chunk pair: scores 2·cq·ck·H·d_qk + PV 2·cq·ck·H·d_v
    per_pair = 2.0 * cq * ck * H * (d_qk + d_v)
    return float(B * n_layers * pairs * per_pair)


def _rwkv_flops(cfg: ModelConfig, tokens: float, n_layers: int,
                chunk: int = 64) -> float:
    """Chunked WKV evaluation: per token per head ≈ 4·D² (state in/out) +
    4·c·D (intra-chunk attention)."""
    if n_layers == 0:
        return 0.0
    D = cfg.rwkv_head_dim
    H = cfg.d_model // D
    per_tok_head = 4.0 * D * D + 4.0 * chunk * D
    return tokens * n_layers * H * per_tok_head


def lm_cell_counts(cfg: ModelConfig, shape: ShapeCase, *, chips: int,
                   tp: int, grad_accum: int, remat: bool,
                   moment_bytes: int, accum_bytes: int,
                   q_chunk: int = 1024, kv_chunk: int = 512,
                   skip_masked: bool = False) -> CellCounts:
    V, d = cfg.vocab_size, cfg.d_model
    n_active = cfg.active_param_count()
    embed_params = V * d
    # matmul params: everything except the embedding gather; the logits
    # matmul always runs (tied adds it back)
    matmul_params = n_active - embed_params
    kinds = cfg.layer_kinds
    n_attn = sum(1 for k in kinds if k == "attn")
    n_rwkv = sum(1 for k in kinds if k == "rwkv6")
    n_moe_layers = (cfg.num_layers - cfg.first_dense_layers) if cfg.is_moe else 0

    if shape.kind == "train":
        B, S = shape.global_batch, shape.seq_len
        tokens = float(B * S)
        Sq = Skv = S
        fwd_passes = 3.0 + (1.0 if remat else 0.0)  # fwd + bwd(2x) + remat
        logits_positions = tokens
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        tokens = float(B * S)
        Sq = Skv = S
        fwd_passes = 1.0
        logits_positions = float(B)  # last_only
        model_flops = 2.0 * n_active * tokens
    else:  # decode
        B = shape.global_batch
        tokens = float(B)
        Sq, Skv = 1, shape.seq_len
        fwd_passes = 1.0
        logits_positions = float(B)
        model_flops = 2.0 * n_active * tokens

    mm = 2.0 * matmul_params * tokens  # includes lm_head if untied
    if cfg.tie_embeddings and cfg.has_lm_head:
        mm += 2.0 * V * d * logits_positions
    elif cfg.has_lm_head and not cfg.tie_embeddings:
        # lm_head already in matmul_params for `tokens`; correct to the
        # actual number of projected positions
        mm -= 2.0 * V * d * (tokens - logits_positions)

    attn = _attn_sched_flops(cfg, Sq, Skv, B, q_chunk, kv_chunk,
                             cfg.local_window, skip_masked, n_attn)
    rwkv = _rwkv_flops(cfg, tokens, n_rwkv)
    # MoE dispatch/combine einsums: each is 2·T·E·C·d flops per layer, so
    # 4·E·C·d per token — the GShard one-hot-matmul tax (known §Perf
    # target: a sort/gather dispatch would remove it entirely)
    moe = 0.0
    if cfg.is_moe and n_moe_layers:
        S_group = shape.seq_len if shape.kind != "decode" else 1
        C = max(int(S_group * cfg.top_k / cfg.num_experts
                    * cfg.capacity_factor), 1)
        if cfg.moe_impl == "sort":
            # sort/gather dispatch: only the router matmul survives
            moe = tokens * n_moe_layers * 2.0 * cfg.num_experts * d
        else:
            moe = tokens * n_moe_layers * (
                4.0 * cfg.num_experts * C * d
                + 2.0 * cfg.num_experts * d  # router
            )

    fwd_flops = mm + attn + rwkv + moe
    flops_global = fwd_flops * fwd_passes
    flops_per_dev = flops_global / chips

    # ---- HBM traffic per device ----
    pb = _bytes_of(cfg.param_dtype)
    P_total = cfg.param_count()
    # weights stream: gathered weights are still TP-sharded -> /tp; read
    # once per pass per microbatch
    weight_stream = P_total * pb / tp * fwd_passes * (
        grad_accum if shape.kind == "train" else 1
    )
    act_bytes = _bytes_of(cfg.dtype)
    tokens_dev = tokens / chips * tp  # activations sharded dp×sp
    act_stream = tokens_dev / tp * d * act_bytes * cfg.num_layers * 12.0
    cache_stream = 0.0
    cache_resident = 0.0
    if shape.kind == "decode":
        cb = _bytes_of(cfg.cache_dtype or cfg.dtype)
        if cfg.attn_kind == "mla":
            per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        else:
            per_tok = 2 * cfg.num_kv_heads * cfg.head_dim
        eff_len = min(cfg.local_window or shape.seq_len, shape.seq_len)
        cache_global = B * eff_len * per_tok * cb * n_attn
        # rwkv/rglru states are tiny by comparison; add them anyway
        state = 0.0
        for k in kinds:
            if k == "rwkv6":
                state += B * (cfg.d_model // cfg.rwkv_head_dim) * \
                    cfg.rwkv_head_dim ** 2 * 4
            elif k == "rglru":
                state += B * cfg.lru_width * 4
        cache_global += state
        cache_stream = cache_global / chips  # read once per decode step
        cache_resident = cache_global / chips
    opt_stream = 0.0
    opt_resident = 0.0
    if shape.kind == "train":
        # p, g, m, v resident; update reads p,m,v,g and writes p,m,v
        opt_resident = P_total * (pb + accum_bytes + 2 * moment_bytes) / chips
        opt_stream = P_total * (4 * pb + 6 * moment_bytes) / chips
    hbm_stream = weight_stream + act_stream + cache_stream + opt_stream

    resid = P_total * pb / chips + opt_resident + cache_resident
    if shape.kind == "train":
        # residual carries for backward: one (B,S,d) per layer per
        # microbatch, sharded dp×sp
        resid += (tokens / grad_accum) / chips * d * act_bytes * cfg.num_layers

    return CellCounts(
        flops_global=flops_global,
        flops_per_dev=flops_per_dev,
        hbm_bytes_per_dev=hbm_stream,
        hbm_resident_per_dev=resid,
        model_flops=model_flops,
        notes={
            "matmul": mm, "attention": attn, "rwkv": rwkv, "moe": moe,
            "fwd_passes": fwd_passes,
            "weight_stream_dev": weight_stream,
            "act_stream_dev": act_stream,
            "cache_stream_dev": cache_stream,
            "opt_stream_dev": opt_stream,
        },
    )
