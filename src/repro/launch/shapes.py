"""The assigned input-shape grid and per-(arch, shape) input_specs.

ShapeDtypeStruct stand-ins only — weak-type-correct, shardable, no device
allocation. The dry-run lowers train_step for `train_*` shapes and
serve steps (prefill/decode) for the inference shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["ShapeCase", "SHAPES", "input_specs", "applicable_shapes",
           "cache_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """DESIGN.md §5 skip rules."""
    out = ["train_4k", "prefill_32k"]
    if cfg.is_encoder_only:
        return out  # no decode step for encoder-only archs
    out.append("decode_32k")
    if cfg.is_subquadratic:
        out.append("long_500k")
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeCase) -> dict:
    """Model-input stand-ins for one grid cell.

    train:   full (B, S) token/label batch (+ frontend stubs).
    prefill: (B, S) prompt tokens.
    decode:  (B, 1) new token; the KV cache spec comes from cache_specs.
    """
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    specs = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((B, S), jnp.int32)
        specs["loss_mask"] = _sds((B, S), jnp.float32)
    if cfg.frontend_stub and cfg.family == "audio":
        specs["features"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        if shape.kind != "decode":
            specs["vision_embeds"] = _sds((B, S, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
            specs["vision_mask"] = _sds((B, S), jnp.bool_)
        specs["positions"] = _sds((B, S, 3), jnp.int32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeCase) -> Optional[dict]:
    """ShapeDtypeStruct tree for the KV cache at this shape (decode /
    prefill), mirroring models.init_cache without allocating."""
    if shape.kind == "train":
        return None
    B = shape.global_batch
    max_len = shape.seq_len
    from repro.models import init_cache

    return jax.eval_shape(lambda: init_cache(cfg, B, max_len))
