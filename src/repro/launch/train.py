"""Training launcher: ``python -m repro.launch.train --arch granite-3-8b
--smoke --steps 100``.

End-to-end driver with everything a production loop needs: sharded params
(mesh-aware), synthetic or file-backed data, checkpoint/restart (elastic),
straggler monitoring, optional cross-pod gradient compression. On this CPU
container run with --smoke (reduced config, local 1-device mesh); on a real
slice the same flags drive the production mesh.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.data import synthetic_batch
from repro.distributed import (
    StepTimer,
    StragglerMonitor,
    latest_step,
    opt_state_shardings,
    param_shardings,
    restore_checkpoint,
    save_checkpoint,
)
from repro.models import init_model
from repro.train import OptimizerConfig, TrainConfig, adamw_init, make_train_step


def parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--grad-accum", type=int, default=1)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--compress-grads", action="store_true",
                   help="bf16 round-trip on gradients (cross-pod simulation)")
    p.add_argument("--log-every", type=int, default=10)
    return p.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)

    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(
            learning_rate=args.lr,
            warmup_steps=max(args.steps // 20, 1),
            total_steps=args.steps,
        ),
        remat=not args.smoke,
        grad_accum=args.grad_accum,
    )
    if args.compress_grads:
        from repro.distributed import bf16_compress

        tcfg = TrainConfig(
            optimizer=tcfg.optimizer, remat=tcfg.remat,
            grad_accum=tcfg.grad_accum, grad_transform=bf16_compress,
        )

    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, tcfg.optimizer)
    start_step = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        psh = param_shardings(mesh, params)
        state = {"params": params, "opt": opt}
        state, start_step = restore_checkpoint(
            args.ckpt_dir, state,
            shardings={"params": psh,
                       "opt": opt_state_shardings(mesh, opt, psh)},
        )
        params, opt = state["params"], state["opt"]
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    monitor = StragglerMonitor(num_hosts=jax.process_count())

    t_start = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=17, step=step)
        with StepTimer(monitor, host=jax.process_index()) as timer:
            params, opt, metrics = step_fn(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
        if step % args.log_every == 0 or step == args.steps - 1:
            stragglers = monitor.stragglers()
            print(
                f"[train] step={step} loss={float(metrics['loss']):.4f} "
                f"lr={float(metrics['lr']):.2e} "
                f"gnorm={float(metrics['grad_norm']):.2f} "
                f"dt={timer.last * 1e3:.0f}ms stragglers={stragglers}"
            )
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1,
                                   {"params": params, "opt": opt})
            print(f"[train] checkpoint -> {path}")
    dt = time.perf_counter() - t_start
    print(f"[train] done: {args.steps - start_step} steps in {dt:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
