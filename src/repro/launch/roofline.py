"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh) cell, from the SPMD-partitioned
module (which is per-device, so no further division by chip count):

    compute_s    = HLO_FLOPs_per_device    / peak_FLOPs      (197 TF bf16)
    memory_s     = HLO_bytes_per_device    / HBM_bw          (819 GB/s)
    collective_s = collective_bytes_per_device / link_bw     (~50 GB/s ICI;
                   'pod'-axis collectives ride DCI at ~25 GB/s)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are NOT in cost_analysis, so we parse the optimized HLO text and sum
result-tensor sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HW", "CollectiveStats", "parse_collective_bytes",
           "roofline_terms", "Roofline", "DeviceModel", "DEVICE_MODELS",
           "detect_device"]

# TPU v5e hardware constants (per chip)
HW = {
    "peak_flops": 197e12,  # bf16
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
    "dci_bw": 25e9,  # B/s cross-pod (approx; 'pod'-axis collectives)
    "hbm_bytes": 16 * 2**30,  # capacity, for fit checks
}


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Per-device roofline constants for *ranking* kernel schedules.

    The autotuner (repro.core.autotune) feeds FLOP/byte models through
    :meth:`time_s` to order candidate assembly plans; absolute accuracy is
    irrelevant as long as relative costs rank correctly — measured refinement
    handles the rest. ``peak_flops``/``mem_bw`` are for the f64 regime the
    FETI substrate runs in (NOT the bf16 LM numbers in ``HW``).

    Attributes:
      kind: jax platform string ("tpu" | "gpu" | "cpu").
      peak_flops: sustained f64 FLOP/s.
      mem_bw: main-memory bandwidth, B/s.
      overhead_s: per-dispatched-op launch/dispatch overhead. This is the
        term that penalizes tiny block sizes (many small ops) and rewards
        fused/pallas single-launch schedules.
    """

    kind: str
    name: str
    peak_flops: float
    mem_bw: float
    overhead_s: float = 5e-6

    def time_s(self, flops: float, bytes_: float, n_ops: int = 1) -> float:
        """Roofline execution-time estimate: max(compute, memory) + launches."""
        return max(flops / self.peak_flops, bytes_ / self.mem_bw) \
            + n_ops * self.overhead_s


DEVICE_MODELS = {
    # v5e f64 is emulated through f32 passes; rough sustained figure.
    "tpu": DeviceModel("tpu", "tpu-v5e-f64", peak_flops=1.0e12,
                       mem_bw=HW["hbm_bw"], overhead_s=2e-6),
    # A100-class card (the paper's hardware), f64 non-tensor-core peak.
    "gpu": DeviceModel("gpu", "a100-f64", peak_flops=9.7e12,
                       mem_bw=1.55e12, overhead_s=5e-6),
    # container-grade CPU; XLA:CPU per-op dispatch is comparatively heavy.
    "cpu": DeviceModel("cpu", "host-f64", peak_flops=5.0e10,
                       mem_bw=2.0e10, overhead_s=10e-6),
}


def detect_device(kind: Optional[str] = None) -> DeviceModel:
    """Resolve a :class:`DeviceModel` from an explicit kind or jax's default
    backend platform; unknown platforms fall back to the CPU model."""
    if kind is None:
        import jax  # local: roofline stays importable without a backend

        kind = jax.devices()[0].platform
    return DEVICE_MODELS.get(kind, DEVICE_MODELS["cpu"])

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# one tensor literal: dtype[d0,d1,...]{layout}   (layout optional)
_TENSOR_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def _shape_bytes(tensors: str) -> int:
    total = 0
    for dtype, dims in _TENSOR_RE.findall(tensors):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result-tensor bytes of every collective in the optimized HLO,
    counting each loop body ONCE (the raw structural schedule).

    ``-start``/``-done`` async pairs are counted once (on the start op —
    done ops repeat the shape and are skipped by the dedup below).
    """
    bytes_by_op = {op: 0 for op in _COLL_OPS}
    count_by_op = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: payload counted at -start
        m = _LINE_RE.search(line)
        if not m:
            continue
        tensors, op = m.group(1), m.group(2)
        bytes_by_op[op] += _shape_bytes(tensors)
        count_by_op[op] += 1
    return CollectiveStats(bytes_by_op=bytes_by_op, count_by_op=count_by_op)


# ------------------------- trip-count-corrected collective accounting ------
# greedy param match: computation params nest tuples, e.g.
#   %body.1 (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:,|\s).*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HEADER_RE.match(line.strip())
            if m and "{" in line:
                current = m.group(1)
                comps[current] = []
                if line.strip().startswith("ENTRY"):
                    entry = current
                continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count heuristic: scan loops compare an induction var against a
    constant bound; take the max integer constant in the condition."""
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def collective_stats_trip_corrected(hlo_text: str) -> CollectiveStats:
    """Like :func:`parse_collective_bytes`, but multiplies collectives
    inside while-loop bodies by the loop trip count (recursively) — XLA's
    own cost/byte counters count loop bodies once, which undercounts
    scanned programs by orders of magnitude."""
    comps, entry = _split_computations(hlo_text)

    def direct(comp_lines):
        b = {op: 0 for op in _COLL_OPS}
        c = {op: 0 for op in _COLL_OPS}
        whiles = []
        for line in comp_lines:
            w = _WHILE_RE.search(line)
            if w:
                whiles.append((w.group(1), w.group(2)))
                continue
            if "-done(" in line:
                continue
            m = _LINE_RE.search(line)
            if m:
                b[m.group(2)] += _shape_bytes(m.group(1))
                c[m.group(2)] += 1
        return b, c, whiles

    memo: dict[str, tuple] = {}

    def total(name: str) -> tuple:
        if name in memo:
            return memo[name]
        lines = comps.get(name, [])
        b, c, whiles = direct(lines)
        memo[name] = (b, c)  # break cycles defensively
        for cond, body in whiles:
            trips = _trip_count(comps.get(cond, []))
            bb, bc = total(body)
            for op in _COLL_OPS:
                b[op] += trips * bb[op]
                c[op] += trips * bc[op]
        memo[name] = (b, c)
        return b, c

    if entry is None:  # defensive: fall back to the flat count
        return parse_collective_bytes(hlo_text)
    b, c = total(entry)
    return CollectiveStats(bytes_by_op=dict(b), count_by_op=dict(c))


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None  # MODEL_FLOPS / (HLO_FLOPs * chips)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(cost: dict, coll: CollectiveStats, chips: int,
                   model_flops: Optional[float] = None,
                   link_bw: float = HW["ici_bw"]) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.total_bytes)
    compute_s = flops / HW["peak_flops"]
    memory_s = bytes_ / HW["hbm_bw"]
    collective_s = cb / link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / max(flops * chips, 1.0)
    return Roofline(
        flops_per_dev=flops,
        bytes_per_dev=bytes_,
        coll_bytes_per_dev=cb,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
    )
