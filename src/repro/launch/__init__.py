"""Launchers: production meshes, the multi-pod dry-run, training and FETI
solve drivers, roofline analysis."""
