"""Production meshes. A FUNCTION, not module state: importing this module
never touches jax device initialization."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis rides
    DCI and carries pure data parallelism + compressed grad reductions."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model=1) mesh — lets the
    same launcher code run on this CPU container."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
