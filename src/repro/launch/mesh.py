"""Production meshes. A FUNCTION, not module state: importing this module
never touches jax device initialization."""
from __future__ import annotations

import os
import re
import sys

import jax
import numpy as np

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_feti_mesh",
    "force_host_device_count",
]


def force_host_device_count(n: int) -> None:
    """Ask XLA for ``n`` host-platform devices (CPU hosts standing in for a
    multi-chip backend). Must run before the jax backend initializes.

    Appends to ``XLA_FLAGS``; when the flag is already present with a
    DIFFERENT count it warns and keeps the existing value — XLA reads the
    first setting and cannot be overridden from here."""
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m:
        have = int(m.group(1))
        if have != n:
            print(
                f"[mesh] XLA_FLAGS already forces {have} host device(s); "
                f"keeping {have} (asked for {n})",
                file=sys.stderr,
            )
        return
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    )


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis rides
    DCI and carries pure data parallelism + compressed grad reductions."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model=1) mesh — lets the
    same launcher code run on this CPU container."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_feti_mesh(n_devices: int | None = None):
    """FETI deployment mesh: one ``("data",)`` axis over the subdomains.

    FETI has no model parallelism — every subdomain's factor/SC lives
    whole on one device and only λ-sized psums cross devices
    (:mod:`repro.feti.sharded`) — so the mesh is one data axis over the
    first ``n_devices`` devices (default: all). Works on any backend,
    including CPU hosts forced to N devices via
    ``--xla_force_host_platform_device_count`` (see launch/solve_feti.py
    ``--devices``).
    """
    devices = jax.devices()
    if n_devices is None:
        n_devices = len(devices)
    if not 1 <= n_devices <= len(devices):
        raise ValueError(
            f"asked for {n_devices} devices, have {len(devices)}"
        )
    return jax.sharding.Mesh(np.array(devices[:n_devices]), ("data",))
