"""FETI solve launcher (the paper's 'serving' equivalent):
``python -m repro.launch.solve_feti --arch feti-heat-2d --smoke``.

Runs preprocess (factorization + sparsity-utilizing SC assembly) and the
PCPG solve for a registered FETI architecture, reports stage timings,
iteration counts and the amortization point, and validates against the
undecomposed global solve.

``--autotune`` replaces the architecture's hand-picked assembly config with
the planner of :mod:`repro.core.autotune` (the paper's Table-1 choice made
automatically), prints the selected plan with predicted-vs-measured cost,
and cross-checks the autotuned SCs against the dense baseline of [9].
"""
from __future__ import annotations

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.configs import FetiArchConfig, get_config, get_smoke_config
from repro.core import SchurAssemblyConfig
from repro.fem import decompose_heat_problem
from repro.feti import FetiSolver


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="feti-heat-2d")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--mode", choices=("explicit", "implicit"),
                   default="explicit")
    p.add_argument("--tol", type=float, default=1e-9)
    p.add_argument("--validate", action="store_true",
                   help="compare against the global sparse solve")
    p.add_argument("--autotune", action="store_true",
                   help="let the plan autotuner pick the assembly config")
    p.add_argument("--no-plan-cache", action="store_true",
                   help="ignore + don't write the on-disk plan cache")
    args = p.parse_args(argv)

    fc = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not isinstance(fc, FetiArchConfig):
        raise SystemExit(f"{args.arch} is not a FETI architecture")

    prob = decompose_heat_problem(fc.dim, fc.sub_grid, fc.elems_per_sub)
    print(f"[feti] {fc.name}: {prob.n_subdomains} subdomains x "
          f"{prob.subdomains[0].n} DOFs, {prob.n_lambda} multipliers")

    if args.autotune:
        cfg = "auto"
    else:
        cfg = SchurAssemblyConfig(
            trsm_variant=fc.trsm_variant, syrk_variant=fc.syrk_variant,
            block_size=fc.block_size, rhs_block_size=fc.rhs_block_size,
        )
    solver = FetiSolver(prob, cfg, mode=args.mode,
                        plan_cache=not args.no_plan_cache)
    sol = solver.solve(tol=args.tol)

    if args.autotune and solver.plan is not None:
        for line in solver.plan.summary().splitlines():
            print(f"[autotune] {line}")
        if solver.state is not None and solver.state.F is not None:
            import jax.numpy as jnp

            from repro.core import schur_dense_baseline

            st = solver.state
            F_ref = jax.vmap(schur_dense_baseline)(st.L, st.Btp)
            err = float(jnp.max(jnp.abs(st.F - F_ref)))
            print(f"[autotune] max |F_auto - F_dense_baseline| = {err:.2e}")
            if err > 1e-8:
                print("[autotune] FAIL: autotuned assembly disagrees with "
                      "the dense baseline")
                return 1
    print(f"[feti] mode={args.mode} iters={sol.iterations} "
          f"residual={sol.residual:.2e} converged={sol.converged}")
    print(f"[feti] preprocess={sol.timings['preprocess_s']:.2f}s "
          f"solve={sol.timings['solve_s']:.2f}s")

    if args.validate:
        u_ref = prob.reference_solution()
        err = np.max(np.abs(sol.u_global - u_ref)) / np.abs(u_ref).max()
        print(f"[feti] rel err vs global solve: {err:.2e}")
        if err > 1e-6:
            return 1
    return 0 if sol.converged else 1


if __name__ == "__main__":
    sys.exit(main())
