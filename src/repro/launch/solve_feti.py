"""FETI solve launcher (the paper's 'serving' equivalent):
``python -m repro.launch.solve_feti --arch feti-heat-2d --smoke``.

Runs preprocess (factorization + sparsity-utilizing SC assembly) and the
PCPG solve for a registered FETI architecture, reports stage timings,
iteration counts and the amortization point, and validates against the
undecomposed global solve.

``--problem {heat,elasticity}`` overrides the architecture's workload:
``elasticity`` solves vector-valued P1 linear elasticity (node-blocked
2-3 DOFs per node) with rigid-body-mode kernels of dimension 3 (2D) / 6
(3D) — the paper's target engineering setting (docs/elasticity.md).
Dedicated ``feti-elasticity-{2d,3d}`` architectures default to it.

``--autotune`` replaces the architecture's hand-picked assembly config with
the planner of :mod:`repro.core.autotune` (the paper's Table-1 choice made
automatically), prints the selected plan with predicted-vs-measured cost,
and cross-checks the autotuned SCs against the dense baseline of [9].

``--devices N`` shards the subdomain axis over an N-device ``("data",)``
mesh (:mod:`repro.feti.sharded`). On hosts with fewer physical devices the
flag forces N host-platform devices via XLA's
``--xla_force_host_platform_device_count``, so the distributed pipeline is
exercised end-to-end on this CPU container; combined with ``--validate``
the sharded solution is additionally checked against a fresh single-device
solve.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="feti-heat-2d")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--problem", choices=("heat", "elasticity"), default=None,
                   help="workload override: scalar heat (1 DOF/node, "
                        "kernel dim 1) or vector linear elasticity "
                        "(2-3 DOFs/node, rigid-body kernel dim 3/6); "
                        "default: the architecture's own problem")
    p.add_argument("--mode", choices=("explicit", "implicit"),
                   default="explicit")
    p.add_argument("--precond", choices=("lumped", "dirichlet", "none"),
                   default="lumped",
                   help="PCPG preconditioner: lumped (B K Bᵀ, free), "
                        "dirichlet (B S_b Bᵀ with the primal boundary "
                        "Schur complement assembled on-device through the "
                        "same sparsity-utilizing pipeline; "
                        "docs/preconditioners.md), or none")
    p.add_argument("--tol", type=float, default=1e-9)
    p.add_argument("--validate", action="store_true",
                   help="compare against the global sparse solve (and, "
                        "with --devices, against a single-device solve)")
    p.add_argument("--autotune", action="store_true",
                   help="let the stage graph's joint planner pick every "
                        "assembly stage's config (docs/stage_graph.md)")
    p.add_argument("--fused", action="store_true",
                   help="use the fused TRSM→SYRK Pallas megakernel "
                        "(stepped_trsm_syrk) instead of the architecture's "
                        "two-kernel schedule; ignored with --autotune "
                        "(the planner already enumerates fused=True)")
    p.add_argument("--storage", choices=("dense", "packed"), default=None,
                   help="factor storage layout: dense (S,n,n) stacks or "
                        "packed block-sparse stacks in the symbolic "
                        "fill-mask layout (docs/packed_storage.md); "
                        "default: the config's choice, or the autotuner's "
                        "with --autotune")
    p.add_argument("--no-plan-cache", action="store_true",
                   help="ignore + don't write the on-disk plan cache")
    p.add_argument("--devices", type=int, default=0, metavar="N",
                   help="shard subdomains over an N-device ('data',) mesh "
                        "(forces N host devices on CPU-only hosts)")
    p.add_argument("--n-rhs", type=int, default=0, metavar="R",
                   help="solve R stacked load cases through the multi-RHS "
                        "block-PCPG service (solve_many: preprocess once, "
                        "stream the batch; docs/multirhs.md) instead of "
                        "the single-load solve; with --validate each "
                        "column is checked against its own global solve")
    args = p.parse_args(argv)

    if args.devices:
        # must precede jax backend init — which is why all jax work
        # happens inside main
        from repro.launch.mesh import force_host_device_count

        force_host_device_count(args.devices)

    import jax

    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro.configs import FetiArchConfig, get_config, get_smoke_config
    from repro.core import SchurAssemblyConfig
    from repro.fem import decompose_problem
    from repro.feti import FetiConfig, FetiSolver
    from repro.launch.mesh import make_feti_mesh

    mesh = None
    if args.devices:
        avail = len(jax.devices())
        if avail < args.devices:
            print(f"[feti] WARNING: asked for {args.devices} devices, "
                  f"backend has {avail} (jax initialized early?); "
                  f"using {avail}")
        mesh = make_feti_mesh(min(args.devices, avail))
        print(f"[feti] mesh: {mesh.shape['data']} device(s) on axis 'data'")

    fc = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not isinstance(fc, FetiArchConfig):
        raise SystemExit(f"{args.arch} is not a FETI architecture")

    problem = args.problem or fc.problem
    prob = decompose_problem(problem, fc.dim, fc.sub_grid, fc.elems_per_sub)
    print(f"[feti] {fc.name}: problem={problem} "
          f"({prob.ndof_per_node} DOF/node, kernel dim {prob.kernel_dim}), "
          f"{prob.n_subdomains} subdomains x {prob.subdomains[0].n} DOFs, "
          f"{prob.n_lambda} multipliers")

    if args.autotune:
        cfg = "auto"
    elif args.fused:
        # the fused megakernel needs Pallas; interpret off-TPU so the
        # smoke lane exercises the exact kernel logic on CPU
        cfg = SchurAssemblyConfig(
            block_size=fc.block_size, rhs_block_size=fc.rhs_block_size,
            use_pallas=True, fused=True,
            interpret=jax.devices()[0].platform != "tpu",
        )
    else:
        cfg = SchurAssemblyConfig(
            trsm_variant=fc.trsm_variant, syrk_variant=fc.syrk_variant,
            block_size=fc.block_size, rhs_block_size=fc.rhs_block_size,
        )
    config = FetiConfig(schur=cfg, mode=args.mode,
                        preconditioner=args.precond,
                        plan_cache=not args.no_plan_cache, mesh=mesh,
                        storage=args.storage)
    solver = FetiSolver(prob, config)
    if args.n_rhs > 0:
        # multi-RHS service: preprocess once, stream a load-case batch
        loads = prob.load_cases(args.n_rhs, kind="sweep")
        sol = solver.solve_many(loads, tol=args.tol)
    else:
        sol = solver.solve(tol=args.tol)

    st = solver.state
    if st is not None:
        by = st.device_bytes()
        print(f"[feti] storage={st.storage} device bytes: "
              f"L={by['L']:,} K={by['K']:,} Btp={by['Btp']:,} "
              f"F={by['F']:,} (dense L would be {by['dense_L']:,})")
        if st.Sb is not None:
            sp = st.split
            shared = " (shared interior factor)" if st.shared_factor else ""
            print(f"[feti] precond=dirichlet: boundary/interior split "
                  f"{sp.n_b}/{sp.n_i} of {sp.n} DOFs, "
                  f"Sb={by['Sb']:,} Btb={by['Btb']:,} bytes{shared}")
            if st.dirichlet_plan is not None:
                for line in st.dirichlet_plan.summary().splitlines():
                    print(f"[autotune:dirichlet] {line}")

    if args.autotune and solver.plan is not None:
        for line in solver.plan.summary().splitlines():
            print(f"[autotune] {line}")
        if solver.state is not None and solver.state.F is not None:
            import jax.numpy as jnp

            from repro.core import schur_dense_baseline
            from repro.sparse import PackedBlocks

            st = solver.state
            L_ref = st.L.unpack() if isinstance(st.L, PackedBlocks) else st.L
            F_ref = jax.vmap(schur_dense_baseline)(L_ref, st.Btp)
            err = float(jnp.max(jnp.abs(st.F - F_ref)))
            print(f"[autotune] max |F_auto - F_dense_baseline| = {err:.2e}")
            if err > 1e-8:
                print("[autotune] FAIL: autotuned assembly disagrees with "
                      "the dense baseline")
                return 1
    if args.n_rhs > 0:
        converged = bool(sol.converged.all())
        iters = " ".join(str(int(i)) for i in sol.iterations)
        print(f"[feti] mode={args.mode} n_rhs={sol.n_rhs} "
              f"(padded {sol.n_rhs_padded}) iters=[{iters}] "
              f"block_iters={sol.block_iterations} converged={converged}")
        print(f"[feti] preprocess={sol.timings['preprocess_s']:.2f}s "
              f"solve_many={sol.timings['solve_many_s']:.2f}s "
              f"per_solve={sol.timings['per_solve_s'] * 1e3:.1f}ms")
        if args.validate:
            refs = prob.reference_solutions(loads)
            scale = np.abs(refs).max()
            err = np.max(np.abs(sol.u_global - refs)) / scale
            print(f"[feti] max per-column rel err vs global solves: "
                  f"{err:.2e}")
            if err > 1e-6:
                return 1
            if mesh is not None:
                ref = FetiSolver(prob, config.replace(mesh=None)
                                 ).solve_many(loads, tol=args.tol)
                du = np.max(np.abs(sol.u_global - ref.u_global))
                print(f"[feti] sharded vs single-device solve_many: "
                      f"max|Δu|={du:.2e}")
                if du > 1e-9:
                    print("[feti] FAIL: sharded solve_many diverged from "
                          "the single-device one")
                    return 1
        return 0 if converged else 1

    print(f"[feti] mode={args.mode} iters={sol.iterations} "
          f"residual={sol.residual:.2e} converged={sol.converged}")
    print(f"[feti] preprocess={sol.timings['preprocess_s']:.2f}s "
          f"solve={sol.timings['solve_s']:.2f}s")

    if args.validate:
        u_ref = prob.reference_solution()
        err = np.max(np.abs(sol.u_global - u_ref)) / np.abs(u_ref).max()
        print(f"[feti] rel err vs global solve: {err:.2e}")
        if err > 1e-6:
            return 1
        if mesh is not None:
            # the distributed run must reproduce the single-device one.
            # With --precond dirichlet the S_b stacks come from a
            # differently-scheduled compiled program under shard_map and
            # agree only to machine epsilon, so the PCPG stopping test can
            # flip by one iteration near the threshold — allow that single
            # flip there; the solution agreement stays strict either way.
            ref = FetiSolver(prob, config.replace(mesh=None)
                             ).solve(tol=args.tol)
            du = np.max(np.abs(sol.u_global - ref.u_global))
            print(f"[feti] sharded vs single-device: max|Δu|={du:.2e} "
                  f"iters {sol.iterations} vs {ref.iterations}")
            iter_slack = 1 if args.precond == "dirichlet" else 0
            if du > 1e-9 or abs(sol.iterations - ref.iterations) > iter_slack:
                print("[feti] FAIL: sharded solve diverged from the "
                      "single-device solve")
                return 1
    return 0 if sol.converged else 1


if __name__ == "__main__":
    sys.exit(main())
