"""Serving driver: batched prefill + decode with a KV cache, greedy
sampling, tokens/s reporting — the inference-side end-to-end example
(decode_32k / long_500k lower this same step at production scale).

    PYTHONPATH=src python examples/serve_decode.py --arch granite-3-8b --steps 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_cache, init_model
from repro.train import make_decode_step, make_prefill_step


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="granite-3-8b",
                   help="architecture id (smoke config is served)")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--steps", type=int, default=32)
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    params = init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.steps

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    if cfg.family == "vlm":
        batch = {"tokens": prompt,
                 "positions": jnp.broadcast_to(
                     jnp.arange(args.prompt_len, dtype=jnp.int32)[None, :, None],
                     (args.batch, args.prompt_len, 3))}
    else:
        batch = {"tokens": prompt}

    cache = init_cache(cfg, args.batch, max_len)
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for t in range(args.steps - 1):
        logits, cache = decode(params, tok, cache,
                               jnp.asarray(args.prompt_len + t, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    tps = args.batch * (args.steps - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name}  batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {t_prefill * 1e3:.1f} ms")
    print(f"decode  {args.steps - 1} steps: {t_decode * 1e3:.1f} ms "
          f"({tps:,.0f} tok/s)")
    print(f"first generated row: {gen[0, :12].tolist()}")
    assert gen.shape == (args.batch, args.steps)


if __name__ == "__main__":
    main()
