"""End-to-end training driver: train a ~100M-param decoder LM for a few
hundred steps on the synthetic learnable stream, with checkpointing and
loss reporting. Defaults are sized to finish on this CPU container; pass
--d-model 768 --layers 12 for the full ~100M run on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax

from repro.data import synthetic_batch
from repro.models import ModelConfig, init_model
from repro.train import OptimizerConfig, TrainConfig, adamw_init, make_train_step
from repro.distributed import save_checkpoint


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--d-model", type=int, default=256)
    p.add_argument("--layers", type=int, default=4)
    p.add_argument("--heads", type=int, default=4)
    p.add_argument("--vocab", type=int, default=4096)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt-dir", default=None)
    args = p.parse_args(argv)

    cfg = ModelConfig(
        name="example-lm", family="dense", num_layers=args.layers,
        d_model=args.d_model, d_ff=args.d_model * 4, vocab_size=args.vocab,
        num_heads=args.heads, num_kv_heads=max(args.heads // 2, 1),
        dtype="float32", param_dtype="float32",
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params")

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(learning_rate=args.lr,
                                  warmup_steps=args.steps // 20,
                                  total_steps=args.steps),
        remat=False,
    )
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    opt = adamw_init(params, tcfg.optimizer)

    first_loss = None
    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=7, step=step)
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        if first_loss is None:
            first_loss = loss
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}")
        if args.ckpt_dir and (step + 1) % 100 == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt})
    dt = time.perf_counter() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"done: {dt:.1f}s  ({tok_s:,.0f} tok/s)  "
          f"loss {first_loss:.3f} -> {loss:.3f}")
    assert loss < first_loss, "training must reduce loss"


if __name__ == "__main__":
    main()
