"""End-to-end FETI solve of the paper's benchmark problem (heat transfer
on a decomposed box), explicit vs implicit dual operator, validated
against the undecomposed global sparse solve.

    PYTHONPATH=src python examples/feti_heat_solve.py --dim 2 --subs 3 --elems 8
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import SchurAssemblyConfig
from repro.fem import decompose_heat_problem
from repro.feti import FetiConfig, FetiSolver


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--dim", type=int, default=2, choices=(2, 3))
    p.add_argument("--subs", type=int, default=3, help="subdomains per axis")
    p.add_argument("--elems", type=int, default=8, help="elements per axis")
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--tol", type=float, default=1e-9)
    args = p.parse_args(argv)

    grid = (args.subs,) * args.dim
    eps = (args.elems,) * args.dim
    prob = decompose_heat_problem(args.dim, grid, eps)
    print(f"decomposition: {len(prob.subdomains)} subdomains x "
          f"{prob.subdomains[0].n} DOFs, {prob.n_lambda} multipliers")

    cfg = SchurAssemblyConfig(block_size=args.block_size,
                              rhs_block_size=args.block_size)
    for mode in ("explicit", "implicit"):
        solver = FetiSolver(prob, FetiConfig(schur=cfg, mode=mode))
        sol = solver.solve(tol=args.tol)
        u_ref = prob.reference_solution()
        err = np.max(np.abs(sol.u_global - u_ref)) / np.abs(u_ref).max()
        print(f"[{mode:9s}] iters={sol.iterations:4d} "
              f"residual={sol.residual:.2e} rel_err_vs_global={err:.2e} "
              f"preprocess={sol.timings['preprocess_s']:.2f}s "
              f"solve={sol.timings['solve_s']:.2f}s")
        assert sol.converged and err < 1e-6


if __name__ == "__main__":
    main()
