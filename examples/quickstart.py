"""Quickstart: the paper's technique in 30 lines.

Assembles one subdomain's dense dual operator F̃ = B̃ K⁺ B̃ᵀ two ways —
the dense baseline of [Homola et al. '25] (§3.1) and this paper's
sparsity-utilizing stepped pipeline — and shows they agree while the
stepped one does a fraction of the FLOPs.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (
    SchurAssemblyConfig,
    assemble_schur,
    assembly_flops,
    build_stepped_meta,
    schur_dense_baseline,
)
from repro.testing import (
    block_fill_mask_from_factor,
    random_feti_like_bt,
    random_lower_banded,
)


def main():
    rng = np.random.default_rng(0)
    n, m = 512, 128  # subdomain DOFs x local Lagrange multipliers
    L = jnp.asarray(random_lower_banded(n, 40, rng))  # Cholesky factor
    Bt = jnp.asarray(random_feti_like_bt(n, m, rng))  # gluing matrix B̃ᵀ

    # symbolic phase (once per decomposition): stepped metadata + block mask
    meta = build_stepped_meta(np.asarray(Bt) != 0, block_size=64)
    mask = block_fill_mask_from_factor(np.asarray(L), 64)

    cfg = SchurAssemblyConfig(trsm_variant="factor_split",
                              syrk_variant="input_split", block_size=64)
    F_opt = assemble_schur(L, Bt, meta, cfg, block_mask=mask)
    F_ref = schur_dense_baseline(L, Bt)

    err = float(jnp.max(jnp.abs(F_opt - F_ref)))
    fl_opt = assembly_flops(meta, cfg)["total"]
    fl_dense = meta.flops_trsm_dense() + meta.flops_syrk_dense()
    print(f"SC size: {m}x{m}   max |F_opt - F_dense| = {err:.2e}")
    print(f"stepped FLOPs: {fl_opt:.3e}  dense FLOPs: {fl_dense:.3e}  "
          f"-> {fl_dense / fl_opt:.2f}x fewer")
    assert err < 1e-9


if __name__ == "__main__":
    main()
