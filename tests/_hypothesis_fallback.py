"""Minimal deterministic stand-in for ``hypothesis`` (optional test dep).

The container image pins what it pins; when the real ``hypothesis`` package
is absent this shim is installed into ``sys.modules`` by ``conftest.py`` so
the property-test modules still *collect and run* instead of dying with
``ModuleNotFoundError`` — each ``@given`` test becomes a seeded random
sweep over the strategy space (fixed PRNG seed → reproducible examples).

Only the tiny surface the test-suite uses is implemented:

    from hypothesis import given, settings, strategies as st
    st.integers(lo, hi) / st.floats(lo, hi) / st.sampled_from(seq)
    @settings(max_examples=..., deadline=...)
    @given(**strategies)

Install the real package (``pip install .[test]``) to get shrinking and
example databases; the fallback intentionally trades those for zero deps.
"""
from __future__ import annotations

import types

_FALLBACK_MAX_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value))
    )


def sampled_from(elements) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda rng: elems[int(rng.integers(0, len(elems)))])


def settings(max_examples: int = _FALLBACK_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        def runner():
            import numpy as np

            rng = np.random.default_rng(_SEED)
            # @settings is conventionally applied ABOVE @given, i.e. to the
            # runner itself — check it first, the raw fn second
            n_examples = getattr(
                runner, "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples",
                        _FALLBACK_MAX_EXAMPLES))
            for _ in range(n_examples):
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                fn(**drawn)

        # NOT functools.wraps: pytest reads the wrapper's signature, and
        # copying the original's would make it inject the strategy params
        # as (nonexistent) fixtures. Zero-arg wrapper, names copied by hand.
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco


def build_module() -> types.ModuleType:
    """Assemble a module object mimicking the ``hypothesis`` package root."""
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    mod.strategies = st_mod
    mod.__fallback__ = True
    return mod
