"""Packed block-sparse factor storage (repro.sparse.packed).

The acceptance bar of the storage refactor: packed and dense paths produce
identical (<=1e-12) factors, TRSM results, dual-operator applications and
PCPG iterates across orderings and block sizes, while the packed L+K
footprint is strictly below dense for every non-trivial fill mask. The
``multidevice``-marked test runs the sharded packed solve against the
single-device one (CI multidevice lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    SchurAssemblyConfig,
    build_stepped_meta,
    make_assembler,
    schur_dense_baseline,
    trsm_factor_split,
    trsm_factor_split_packed,
)
from repro.fem import decompose_problem
from repro.feti import FetiConfig, FetiSolver
from repro.feti.assembly import preprocess_cluster
from repro.feti.operator import (
    dual_rhs,
    implicit_dual_apply,
    lumped_preconditioner,
    solve_with_factor,
)
from repro.sparse import (
    PackedBlockIndex,
    PackedBlocks,
    block_cholesky,
    block_cholesky_packed,
    block_pattern,
    block_symbolic_cholesky,
    matrix_pattern_from_elems,
    nested_dissection_order,
    pack_factor,
    packed_symm_matvec,
    packed_tri_solve,
)
from repro.testing import (
    random_banded_spd,
    random_feti_like_bt,
    random_lower_banded,
)

multidevice = pytest.mark.multidevice

CFG_P = SchurAssemblyConfig(block_size=8, rhs_block_size=8, storage="packed")
CFG_D = SchurAssemblyConfig(block_size=8, rhs_block_size=8, storage="dense")


# --------------------------------------------------------------------------
# the container: pack / unpack / index invariants
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 48), bs=st.integers(2, 16),
       seed=st.integers(0, 2**31 - 1))
def test_property_pack_unpack_roundtrip(n, bs, seed):
    """Pack -> unpack reproduces any matrix covered by the index exactly."""
    rng = np.random.default_rng(seed)
    L = random_lower_banded(n, min(n - 1, 6), rng)
    pat = (np.abs(L) + np.abs(L.T)) > 0
    idx = PackedBlockIndex.from_mask(
        block_symbolic_cholesky(block_pattern(pat, bs)), n, bs)
    pb = pack_factor(jnp.asarray(L), idx)
    np.testing.assert_array_equal(np.asarray(pb.unpack()), L)
    # the layout invariant the Pallas kernel relies on: slots are (row,
    # col)-sorted, so each row's diagonal block is its last slot
    assert np.array_equal(idx.cols[idx.diag_slots], np.arange(idx.nb))
    lex = np.lexsort((idx.cols, idx.rows))
    assert np.array_equal(lex, np.arange(idx.n_blocks))


def test_index_rejects_bad_shapes_and_missing_blocks():
    idx = PackedBlockIndex.full(10, 4)
    with pytest.raises(ValueError):
        idx.unpack(jnp.zeros((idx.n_blocks + 1, 4, 4)))
    with pytest.raises(ValueError):
        idx.pack(jnp.zeros((11, 11)))
    sparse_idx = PackedBlockIndex.from_mask(
        np.eye(3, dtype=bool), n=12, bs=4)
    with pytest.raises(KeyError):
        sparse_idx.slot(2, 0)


# --------------------------------------------------------------------------
# packed numerical Cholesky == dense masked path, across orderings/sizes
# --------------------------------------------------------------------------


def _subdomain(ordering: str, shape=(7, 7)):
    from repro.fem import assemble_dense, p1_element_stiffness, structured_mesh
    from repro.fem.regularization import fixing_node_regularization
    from repro.sparse import rcm_order

    mesh = structured_mesh(tuple(s - 1 for s in shape))
    Ke = p1_element_stiffness(mesh.coords, mesh.elems)
    K = np.asarray(assemble_dense(mesh.n_nodes, mesh.elems, Ke))
    K = fixing_node_regularization(K, fixing_node=0)
    n = K.shape[0]
    if ordering == "nd":
        perm = nested_dissection_order(shape)
    elif ordering == "rcm":
        perm = rcm_order(shape)
    else:
        perm = np.arange(n)
    Kp = K[perm][:, perm]
    pat = matrix_pattern_from_elems(n, mesh.elems)[perm][:, perm]
    return Kp, pat


@pytest.mark.parametrize("ordering", ["nd", "rcm", "natural"])
@pytest.mark.parametrize("bs", [4, 8, 16])
def test_packed_cholesky_matches_dense_masked(ordering, bs):
    Kp, pat = _subdomain(ordering)
    mask = block_symbolic_cholesky(block_pattern(pat, bs))
    idx = PackedBlockIndex.from_mask(mask, Kp.shape[0], bs)
    Ld = np.asarray(block_cholesky(jnp.asarray(Kp), bs, mask=mask))
    Lp = np.asarray(block_cholesky_packed(jnp.asarray(Kp), idx).unpack())
    np.testing.assert_allclose(Lp, Ld, rtol=0, atol=1e-12)
    np.testing.assert_allclose(Lp, np.linalg.cholesky(Kp), rtol=1e-8,
                               atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(12, 40), bs=st.integers(3, 12),
       seed=st.integers(0, 2**31 - 1))
def test_property_packed_cholesky_random_spd(n, bs, seed):
    rng = np.random.default_rng(seed)
    K = random_banded_spd(n, min(n - 1, 7), rng)
    mask = block_symbolic_cholesky(block_pattern(np.abs(K) > 0, bs))
    idx = PackedBlockIndex.from_mask(mask, n, bs)
    pb = block_cholesky_packed(jnp.asarray(K), idx)
    L = np.asarray(pb.unpack())
    np.testing.assert_allclose(L @ L.T, K, rtol=1e-8, atol=1e-8)
    assert np.allclose(L, np.tril(L))


# --------------------------------------------------------------------------
# packed solves / matvec / TRSM
# --------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(12, 40), bs=st.integers(3, 12),
       seed=st.integers(0, 2**31 - 1))
def test_property_packed_tri_solve_and_matvec(n, bs, seed):
    rng = np.random.default_rng(seed)
    K = random_banded_spd(n, min(n - 1, 7), rng)
    mask = block_symbolic_cholesky(block_pattern(np.abs(K) > 0, bs))
    idx = PackedBlockIndex.from_mask(mask, n, bs)
    pb = block_cholesky_packed(jnp.asarray(K), idx)
    L = np.asarray(pb.unpack())
    b = rng.standard_normal(n)
    np.testing.assert_allclose(
        np.asarray(packed_tri_solve(pb, jnp.asarray(b))),
        np.linalg.solve(L, b), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(
        np.asarray(packed_tri_solve(pb, jnp.asarray(b), transpose=True)),
        np.linalg.solve(L.T, b), rtol=1e-9, atol=1e-9)
    # symmetric matvec on packed K (lower blocks only; diagonal blocks
    # store their full symmetric tile)
    pk = PackedBlocks(idx.pack(jnp.asarray(K)), idx)
    np.testing.assert_allclose(
        np.asarray(packed_symm_matvec(pk, jnp.asarray(b))),
        K @ b, rtol=1e-10, atol=1e-10)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(16, 48), m=st.integers(4, 20), bs=st.integers(4, 16),
       seed=st.integers(0, 2**31 - 1))
def test_property_packed_trsm_matches_dense(n, m, bs, seed):
    """trsm_factor_split_packed == the dense pruned factor_split path."""
    rng = np.random.default_rng(seed)
    L = random_lower_banded(n, min(10, n - 1), rng)
    Bt = random_feti_like_bt(n, m, rng)
    meta = build_stepped_meta(Bt != 0, block_size=bs, rhs_block_size=bs)
    mask = block_symbolic_cholesky(
        block_pattern((np.abs(L) + np.abs(L.T)) > 0, bs))
    idx = PackedBlockIndex.from_mask(mask, n, bs)
    pb = pack_factor(jnp.asarray(L), idx)
    Bp = jnp.asarray(Bt)[:, meta.perm]
    Yd = np.asarray(trsm_factor_split(jnp.asarray(L), Bp, meta,
                                      block_mask=mask))
    Yp = np.asarray(trsm_factor_split_packed(pb, Bp, meta))
    np.testing.assert_allclose(Yp, Yd, rtol=0, atol=1e-12)


def test_packed_pallas_trsm_matches_reference_interpret():
    rng = np.random.default_rng(7)
    n, m, bs = 48, 18, 8
    L = random_lower_banded(n, 10, rng)
    Bt = random_feti_like_bt(n, m, rng)
    meta = build_stepped_meta(Bt != 0, block_size=bs, rhs_block_size=bs)
    mask = block_symbolic_cholesky(
        block_pattern((np.abs(L) + np.abs(L.T)) > 0, bs))
    idx = PackedBlockIndex.from_mask(mask, n, bs)
    pb = pack_factor(jnp.asarray(L), idx)
    Bp = jnp.asarray(Bt)[:, meta.perm]
    from repro.kernels.ops import stepped_trsm_packed

    Y = np.asarray(stepped_trsm_packed(pb, Bp, meta, interpret=True))
    ref = np.asarray(jax.lax.linalg.triangular_solve(
        jnp.asarray(L), Bp, left_side=True, lower=True))
    np.testing.assert_allclose(Y, ref, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_packed_assembler_matches_dense_baseline(use_pallas):
    rng = np.random.default_rng(3)
    n, m, bs = 40, 16, 8
    L = random_lower_banded(n, 9, rng)
    Bt = random_feti_like_bt(n, m, rng)
    meta = build_stepped_meta(Bt != 0, block_size=bs, rhs_block_size=bs)
    mask = block_symbolic_cholesky(
        block_pattern((np.abs(L) + np.abs(L.T)) > 0, bs))
    idx = PackedBlockIndex.from_mask(mask, n, bs)
    pb = pack_factor(jnp.asarray(L), idx)
    cfg = SchurAssemblyConfig(
        trsm_variant="factor_split", syrk_variant="input_split",
        block_size=bs, rhs_block_size=bs, storage="packed",
        use_pallas=use_pallas, interpret=use_pallas)
    F = np.asarray(make_assembler(meta, cfg, mask)(pb, jnp.asarray(Bt)))
    F_ref = np.asarray(schur_dense_baseline(jnp.asarray(L), jnp.asarray(Bt)))
    np.testing.assert_allclose(F, F_ref, rtol=1e-9, atol=1e-9)


# --------------------------------------------------------------------------
# the FETI pipeline: packed == dense end-to-end (the acceptance criterion)
# --------------------------------------------------------------------------


# both workloads (heat kernel dim 1, elasticity node-blocked vector DOFs
# kernel dim 3) × both PCPG preconditioners — packed storage must be
# numerically invisible in every combination. PR 4 pinned the lumped
# elasticity grid at 4x4 elements because the f64 dual residual floored
# above the tight 1e-10 tolerance on larger grids; the QR-derived coarse
# factor removed that floor and the dirichlet preconditioner converges in
# strictly fewer iterations, so its case runs the full 8x8 grid (162
# DOFs) the lumped case had to give up.
PRECOND_CASES = [
    ("heat", "lumped", (8, 8)),
    ("elasticity", "lumped", (4, 4)),
    ("elasticity", "dirichlet", (8, 8)),
]


@pytest.fixture(scope="module", params=PRECOND_CASES,
                ids=[f"{p}-{pc}" for p, pc, _ in PRECOND_CASES])
def case2d(request):
    problem, precond, eps = request.param
    return decompose_problem(problem, 2, (2, 2), eps), precond


@pytest.fixture(scope="module")
def prob2d(case2d):
    return case2d[0]


@pytest.fixture(scope="module")
def states(case2d):
    prob, precond = case2d
    dirichlet = precond == "dirichlet"
    pre = "dirichlet" if dirichlet else "lumped"
    return (preprocess_cluster(prob, FetiConfig(schur=CFG_D,
                                                preconditioner=pre)),
            preprocess_cluster(prob, FetiConfig(schur=CFG_P,
                                                preconditioner=pre)))


def test_packed_state_layout_and_footprint(states):
    """Packed L is a PackedBlocks stack; K is packed in BOTH modes; the
    packed L+K footprint is strictly below dense for this non-trivial
    fill mask."""
    st_d, st_p = states
    assert st_d.storage == "dense" and st_p.storage == "packed"
    assert isinstance(st_p.L, PackedBlocks)
    assert isinstance(st_d.K, PackedBlocks)  # no dense K in either mode
    assert isinstance(st_p.K, PackedBlocks)
    bd, bp = st_d.device_bytes(), st_p.device_bytes()
    # non-trivial mask: fewer stored blocks than the full lower triangle
    nb = st_p.index.nb
    assert st_p.index.n_blocks < nb * (nb + 1) // 2
    assert bp["L"] < bd["L"]
    assert bp["L"] + bp["K"] < bd["dense_L"] + bd["dense_K"]


def test_packed_factor_and_sc_match_dense(states):
    st_d, st_p = states
    np.testing.assert_allclose(
        np.asarray(st_p.L.unpack()), np.asarray(st_d.L),
        rtol=0, atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(st_p.F), np.asarray(st_d.F), rtol=0, atol=1e-12)
    if st_d.Sb is not None:
        # the dirichlet stage's primal Schur complements: packed interior
        # factors must reproduce the dense ones through TRSM+SYRK too
        scale = np.abs(np.asarray(st_d.Sb)).max()
        np.testing.assert_allclose(
            np.asarray(st_p.Sb), np.asarray(st_d.Sb),
            rtol=0, atol=1e-12 * max(scale, 1.0))


def test_packed_operators_match_dense(states, prob2d):
    st_d, st_p = states
    nl = prob2d.n_lambda
    rng = np.random.default_rng(0)
    lam = jnp.asarray(rng.standard_normal(nl))
    qi_d = implicit_dual_apply(st_d.L, st_d.Btp, st_d.lambda_ids, nl, lam)
    qi_p = implicit_dual_apply(st_p.L, st_p.Btp, st_p.lambda_ids, nl, lam)
    np.testing.assert_allclose(np.asarray(qi_p), np.asarray(qi_d),
                               rtol=0, atol=1e-12)
    w_d = lumped_preconditioner(st_d.K, st_d.Btp, st_d.lambda_ids, nl, lam)
    w_p = lumped_preconditioner(st_p.K, st_p.Btp, st_p.lambda_ids, nl, lam)
    np.testing.assert_allclose(np.asarray(w_p), np.asarray(w_d),
                               rtol=0, atol=1e-12)
    if st_d.Sb is not None:
        from repro.feti.operator import dirichlet_preconditioner

        v_d = dirichlet_preconditioner(st_d.Sb, st_d.Btb, st_d.lambda_ids,
                                       nl, lam)
        v_p = dirichlet_preconditioner(st_p.Sb, st_p.Btb, st_p.lambda_ids,
                                       nl, lam)
        np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_d),
                                   rtol=0, atol=1e-11)
    c = jnp.zeros((nl,))
    d_d = dual_rhs(st_d.L, st_d.Btp, st_d.fp, st_d.lambda_ids, nl, c)
    d_p = dual_rhs(st_p.L, st_p.Btp, st_p.fp, st_p.lambda_ids, nl, c)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_d),
                               rtol=0, atol=1e-12)
    # solve_with_factor: the shared fwd/bwd pair, dense vs packed
    rhs = jnp.asarray(rng.standard_normal(st_d.fp.shape))
    np.testing.assert_allclose(
        np.asarray(solve_with_factor(st_p.L, rhs)),
        np.asarray(solve_with_factor(st_d.L, rhs)), rtol=0, atol=1e-11)


@pytest.mark.parametrize("ordering", ["nd", "rcm", "natural"])
@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_packed_solve_matches_dense_iterates(case2d, ordering, mode):
    """Same PCPG iterate count, same multipliers, same solution — packed
    storage is numerically invisible (for the lumped AND the dirichlet
    preconditioner; the dirichlet case runs the 8x8 elasticity grid the
    old floor forced the lumped case to pin at 4x4)."""
    prob, precond = case2d
    fc = FetiConfig(mode=mode, preconditioner=precond, ordering=ordering)
    sol_d = FetiSolver(prob, fc.replace(schur=CFG_D)).solve(tol=1e-10)
    sol_p = FetiSolver(prob, fc.replace(schur=CFG_P)).solve(tol=1e-10)
    assert sol_d.converged and sol_p.converged
    if precond == "lumped":
        assert sol_d.iterations == sol_p.iterations
        np.testing.assert_allclose(sol_p.lam, sol_d.lam, rtol=0, atol=5e-12)
        np.testing.assert_allclose(sol_p.u_global, sol_d.u_global,
                                   rtol=0, atol=5e-12)
    else:
        # the dirichlet S_b agrees across storages only to ~1e-15·‖S‖
        # (the packed TRSM schedules the same flops through K_ii⁻¹ in a
        # different order); near the stopping threshold that can shift
        # convergence by one iteration, so equality is on the solution
        assert abs(sol_d.iterations - sol_p.iterations) <= 1
        np.testing.assert_allclose(sol_p.u_global, sol_d.u_global,
                                   rtol=0, atol=1e-9)
    u_ref = prob.reference_solution()
    np.testing.assert_allclose(sol_p.u_global, u_ref,
                               atol=1e-6 * np.abs(u_ref).max())


@pytest.mark.parametrize("bs", [4, 8, 16])
def test_packed_solve_across_block_sizes(case2d, bs):
    prob, precond = case2d
    cfg_d = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs,
                                storage="dense")
    cfg_p = SchurAssemblyConfig(block_size=bs, rhs_block_size=bs,
                                storage="packed")
    sol_d = FetiSolver(prob, FetiConfig(
        schur=cfg_d, preconditioner=precond)).solve(tol=1e-10)
    sol_p = FetiSolver(prob, FetiConfig(
        schur=cfg_p, preconditioner=precond)).solve(tol=1e-10)
    if precond == "lumped":
        assert sol_d.iterations == sol_p.iterations
        np.testing.assert_allclose(sol_p.u_global, sol_d.u_global,
                                   rtol=0, atol=5e-12)
    else:  # see test_packed_solve_matches_dense_iterates
        assert abs(sol_d.iterations - sol_p.iterations) <= 1
        np.testing.assert_allclose(sol_p.u_global, sol_d.u_global,
                                   rtol=0, atol=1e-9)


def test_storage_override_knob(prob2d):
    """The storage= knob on preprocess_cluster/FetiSolver overrides the
    config's layout without touching anything else."""
    st = preprocess_cluster(prob2d, FetiConfig(schur=CFG_D,
                                               storage="packed"))
    assert st.storage == "packed" and st.cfg.storage == "packed"
    solver = FetiSolver(prob2d, FetiConfig(schur=CFG_P, storage="dense"))
    solver.preprocess()
    assert solver.state.storage == "dense"


def test_implicit_mode_keeps_packed_factor(prob2d):
    st = preprocess_cluster(prob2d, FetiConfig(schur=CFG_P,
                                               mode="implicit"))
    assert st.F is None
    assert isinstance(st.L, PackedBlocks)


# --------------------------------------------------------------------------
# sharded packed pipeline (CI multidevice lane)
# --------------------------------------------------------------------------


@multidevice
@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_sharded_packed_solve_matches_single_device(case2d, mode):
    from repro.launch.mesh import make_feti_mesh

    prob, precond = case2d
    mesh = make_feti_mesh()
    fc = FetiConfig(schur=CFG_P, mode=mode, preconditioner=precond)
    sol_sh = FetiSolver(prob, fc.replace(mesh=mesh)).solve(tol=1e-10)
    sol1 = FetiSolver(prob, fc).solve(tol=1e-10)
    assert sol_sh.converged and sol1.converged
    # dirichlet: the shard_map-compiled S_b matches single-device only to
    # machine epsilon, which can flip the stopping test by one iteration
    slack = 0 if precond == "lumped" else 1
    assert abs(sol_sh.iterations - sol1.iterations) <= slack
    assert np.max(np.abs(sol_sh.u_global - sol1.u_global)) < 1e-9


@multidevice
def test_sharded_packed_state_is_packed(prob2d):
    from repro.feti import sharded as shlib
    from repro.launch.mesh import make_feti_mesh

    mesh = make_feti_mesh()
    st = preprocess_cluster(prob2d, FetiConfig(schur=CFG_P, mesh=mesh))
    assert isinstance(st.L, PackedBlocks)
    assert st.S % shlib.mesh_size(mesh) == 0
    # dummy padding subdomains factorize to identity in packed form too
    L_dense = np.asarray(st.L.unpack())
    for s in range(st.S_real, st.S):
        np.testing.assert_allclose(L_dense[s], np.eye(L_dense.shape[1]),
                                   rtol=0, atol=1e-12)
