"""Distributed FETI (repro.feti.sharded) and the relabeled-multiplier path.

Single-device tests cover the host-side placement helpers and the
``col_perm=None`` assembler equivalence — the property the sharded
deployment is built on: relabeling the local multiplier columns host-side
commutes with the whole assembly, for dense and sparse variants alike.

Tests marked ``multidevice`` compare the sharded pipeline (assembly, dual
operators, coarse problem, full PCPG solve) against the single-device one.
They auto-skip unless the backend has >=2 devices (tests/conftest.py); the
CI ``multidevice`` lane forces 8 host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SchurAssemblyConfig, build_stepped_meta, shared_envelope
from repro.fem import decompose_problem
from repro.feti import FetiConfig, FetiSolver
from repro.feti import sharded as shlib
from repro.feti.assembly import batched_assemble, preprocess_cluster
from repro.feti.operator import (
    explicit_dual_apply,
    implicit_dual_apply,
    lumped_preconditioner,
)
from repro.launch.mesh import make_feti_mesh
from repro.testing import random_feti_like_bt, random_lower_banded

CFG = SchurAssemblyConfig(block_size=8, rhs_block_size=8)

multidevice = pytest.mark.multidevice


# both workloads: the sharded pipeline must reproduce the single-device
# one with kernel dimension 1 (heat) AND > 1 (elasticity rigid bodies,
# k = 3 → the coarse G carries 3 columns per subdomain shard)
@pytest.fixture(scope="module", params=["heat", "elasticity"])
def prob(request):
    return decompose_problem(request.param, 2, (2, 2), (4, 4))


@pytest.fixture(scope="module")
def single(prob):
    return preprocess_cluster(prob, CFG)


@pytest.fixture(scope="module")
def mesh():
    return make_feti_mesh()


@pytest.fixture(scope="module")
def sharded_state(prob, mesh):
    return preprocess_cluster(prob, FetiConfig(schur=CFG, mesh=mesh))


def _bt_stack(prob):
    return np.stack([sd.Bt for sd in prob.subdomains])


def _relabeled_padded_bt(prob, st1, st_sh, mesh):
    """Original-row-order B̃ᵀ in the sharded layout (relabeled + padded)."""
    Bt_rel = shlib.relabel_columns(_bt_stack(prob), np.asarray(st1.col_perm))
    return shlib.shard_stack(mesh, shlib.pad_stack(Bt_rel, st_sh.S))


# --------------------------------------------------------------------------
# host-side helpers (single device)
# --------------------------------------------------------------------------


def test_pad_stack_zero_and_identity():
    x = np.arange(12.0).reshape(2, 3, 2)
    padded = shlib.pad_stack(x, 4)
    assert padded.shape == (4, 3, 2)
    np.testing.assert_array_equal(padded[:2], x)
    np.testing.assert_array_equal(padded[2:], 0.0)
    sq = np.ones((2, 3, 3))
    eye = shlib.pad_stack(sq, 3, identity=True)
    np.testing.assert_array_equal(eye[:2], sq)
    np.testing.assert_array_equal(eye[2], np.eye(3))
    assert shlib.pad_stack(x, 2) is x
    with pytest.raises(ValueError):
        shlib.pad_stack(x, 1)


def test_relabel_columns_is_the_column_permutation():
    rng = np.random.default_rng(0)
    stack = rng.standard_normal((3, 5, 4))
    perm = np.stack([rng.permutation(4) for _ in range(3)])
    out = shlib.relabel_columns(stack, perm)
    for s in range(3):
        np.testing.assert_array_equal(out[s], stack[s][:, perm[s]])
    # 2-d stacks (lambda_ids) relabel identically
    ids = rng.integers(0, 9, size=(3, 4))
    out2 = shlib.relabel_columns(ids, perm)
    for s in range(3):
        np.testing.assert_array_equal(out2[s], ids[s][perm[s]])


def test_mesh_size_requires_data_axis():
    bad = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError):
        shlib.mesh_size(bad)


def test_padded_count_single_device_is_identity():
    mesh = make_feti_mesh(1)
    assert shlib.padded_count(5, mesh) == 5


# --------------------------------------------------------------------------
# the relabeled (col_perm=None) assembler path == the permuted path
# --------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    trsm=st.sampled_from(["dense", "rhs_split", "factor_split"]),
    syrk=st.sampled_from(["dense", "input_split", "output_split"]),
    n=st.integers(16, 48),
    m=st.integers(4, 20),
    bs=st.integers(4, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_relabeled_path_matches_permuted_path(trsm, syrk, n, m, bs, seed):
    """Property: for ANY random cluster and ANY dense/sparse variant combo,
    ``batched_assemble(col_perm=None)`` on host-relabeled B̃ᵀ equals the
    runtime-permuted path up to the relabeling permutation, and both equal
    B̃ K⁻¹ B̃ᵀ."""
    rng = np.random.default_rng(seed)
    S = 3
    L = np.stack([random_lower_banded(n, min(8, n - 1), rng) for _ in range(S)])
    Bt = np.stack([random_feti_like_bt(n, m, rng) for _ in range(S)])
    metas = [build_stepped_meta(b != 0, block_size=bs, rhs_block_size=bs) for b in Bt]
    env = shared_envelope(metas)
    cp = np.stack([me.perm for me in metas])
    icp = np.stack([me.inv_perm for me in metas])
    cfg = SchurAssemblyConfig(
        trsm_variant=trsm,
        syrk_variant=syrk,
        block_size=bs,
        rhs_block_size=bs,
    )

    F_perm = np.asarray(
        batched_assemble(
            jnp.asarray(L),
            jnp.asarray(Bt),
            jnp.asarray(cp),
            jnp.asarray(icp),
            env,
            cfg,
            None,
        )
    )
    Bt_rel = shlib.relabel_columns(Bt, cp)
    F_rel = np.asarray(
        batched_assemble(
            jnp.asarray(L),
            jnp.asarray(Bt_rel),
            None,
            None,
            env,
            cfg,
            None,
        )
    )
    for s in range(S):
        np.testing.assert_allclose(
            F_rel[s],
            F_perm[s][cp[s]][:, cp[s]],
            rtol=1e-10,
            atol=1e-10,
        )
        K = L[s] @ L[s].T
        want = Bt[s].T @ np.linalg.solve(K, Bt[s])
        np.testing.assert_allclose(F_perm[s], want, rtol=1e-7, atol=1e-7)


@pytest.mark.parametrize(
    "trsm,syrk",
    [
        ("dense", "dense"),
        ("rhs_split", "input_split"),
        ("factor_split", "output_split"),
    ],
)
def test_cluster_relabeled_assembly_matches_state(prob, trsm, syrk):
    """Same equivalence on a REAL cluster state: the relabeled assembler
    reproduces ``ClusterState.F`` (which used the permuted path) up to each
    subdomain's stepped relabeling."""
    cfg = SchurAssemblyConfig(
        trsm_variant=trsm,
        syrk_variant=syrk,
        block_size=8,
        rhs_block_size=8,
    )
    st1 = preprocess_cluster(prob, cfg)
    cp = np.asarray(st1.col_perm)
    Btp_rel = shlib.relabel_columns(np.asarray(st1.Btp), cp)
    F_rel = np.asarray(
        batched_assemble(
            st1.L,
            jnp.asarray(Btp_rel),
            None,
            None,
            st1.env,
            cfg,
            st1.block_mask,
        )
    )
    F = np.asarray(st1.F)
    for s in range(F.shape[0]):
        np.testing.assert_allclose(
            F_rel[s],
            F[s][cp[s]][:, cp[s]],
            rtol=1e-10,
            atol=1e-10,
        )


# --------------------------------------------------------------------------
# sharded pipeline == single-device pipeline (the CI multidevice lane)
# --------------------------------------------------------------------------


@multidevice
def test_padded_count_rounds_up_to_mesh_multiple(mesh):
    D = shlib.mesh_size(mesh)
    assert D >= 2
    assert shlib.padded_count(1, mesh) == D
    assert shlib.padded_count(D, mesh) == D
    assert shlib.padded_count(D + 1, mesh) == 2 * D


@multidevice
def test_sharded_assembly_matches_batched(prob, mesh, single, sharded_state):
    """The sharded assembler's F equals the single-device batched_assemble
    result (up to the relabeling); padded dummy subdomains assemble to 0."""
    st1, st_sh = single, sharded_state
    S_real = st_sh.S_real
    assert st_sh.S % shlib.mesh_size(mesh) == 0
    assert S_real == len(prob.subdomains)
    cp = np.asarray(st1.col_perm)
    F1 = np.asarray(st1.F)
    F_sh = np.asarray(st_sh.F)
    for s in range(S_real):
        np.testing.assert_allclose(
            F_sh[s],
            F1[s][cp[s]][:, cp[s]],
            rtol=1e-10,
            atol=1e-10,
        )
    np.testing.assert_array_equal(F_sh[S_real:], 0.0)
    # factors of the real subdomains are untouched by sharding
    np.testing.assert_allclose(
        np.asarray(st_sh.L)[:S_real],
        np.asarray(st1.L),
        rtol=1e-12,
        atol=1e-12,
    )


@multidevice
def test_sharded_dual_operators_match(prob, mesh, single, sharded_state):
    st1, st_sh = single, sharded_state
    nl = prob.n_lambda
    rng = np.random.default_rng(3)
    lam = jnp.asarray(rng.standard_normal(nl))

    q1 = explicit_dual_apply(st1.F, st1.lambda_ids, nl, lam)
    q_sh = shlib.explicit_dual_apply(mesh, st_sh.F, st_sh.lambda_ids, nl, lam)
    np.testing.assert_allclose(np.asarray(q_sh), np.asarray(q1), rtol=1e-10, atol=1e-10)

    qi1 = implicit_dual_apply(st1.L, st1.Btp, st1.lambda_ids, nl, lam)
    qi_sh = shlib.implicit_dual_apply(
        mesh,
        st_sh.L,
        st_sh.Btp,
        st_sh.lambda_ids,
        nl,
        lam,
    )
    np.testing.assert_allclose(
        np.asarray(qi_sh),
        np.asarray(qi1),
        rtol=1e-10,
        atol=1e-10,
    )

    # K is packed in factor row order and pairs with Btp (feti.assembly)
    w1 = lumped_preconditioner(st1.K, st1.Btp, st1.lambda_ids, nl, lam)
    w_sh = shlib.lumped_preconditioner(
        mesh,
        st_sh.K,
        st_sh.Btp,
        st_sh.lambda_ids,
        nl,
        lam,
    )
    np.testing.assert_allclose(np.asarray(w_sh), np.asarray(w1), rtol=1e-10, atol=1e-10)


@multidevice
def test_sharded_coarse_problem_matches(prob, mesh, single, sharded_state):
    from repro.feti.projector import build_coarse_problem as build_single

    st1, st_sh = single, sharded_state
    nl = prob.n_lambda
    c1 = build_single(
        jnp.asarray(_bt_stack(prob)),
        st1.f,
        st1.R,
        st1.lambda_ids,
        nl,
    )
    c_sh = shlib.build_coarse_problem(
        mesh,
        _relabeled_padded_bt(prob, st1, st_sh, mesh),
        st_sh.f,
        st_sh.R,
        st_sh.lambda_ids,
        nl,
        S_real=st_sh.S_real,
    )
    np.testing.assert_allclose(
        np.asarray(c_sh.lambda0()),
        np.asarray(c1.lambda0()),
        rtol=1e-9,
        atol=1e-12,
    )
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(nl))
    np.testing.assert_allclose(
        np.asarray(c_sh.project(x)),
        np.asarray(c1.project(x)),
        rtol=1e-9,
        atol=1e-12,
    )


@multidevice
@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_sharded_solve_matches_single_device(prob, mesh, mode):
    """The acceptance bar: same u_global (to 1e-9) and same iteration count
    as the single-device solve, and both match the undecomposed solve."""
    fc = FetiConfig(schur=CFG, mode=mode)
    sol_sh = FetiSolver(prob, fc.replace(mesh=mesh)).solve(tol=1e-10)
    sol1 = FetiSolver(prob, fc).solve(tol=1e-10)
    assert sol_sh.converged and sol1.converged
    assert sol_sh.iterations == sol1.iterations
    assert np.max(np.abs(sol_sh.u_global - sol1.u_global)) < 1e-9
    u_ref = prob.reference_solution()
    scale = np.abs(u_ref).max()
    np.testing.assert_allclose(sol_sh.u_global, u_ref, atol=1e-6 * scale)


@multidevice
def test_sharded_coarse_problem_carries_kernel_columns(prob, sharded_state):
    """G has k columns per (padded) subdomain — kernel dim > 1 for the
    elasticity parametrization."""
    st_sh = sharded_state
    k = st_sh.R.shape[2]
    assert k == (1 if prob.problem == "heat" else 3)


@multidevice
def test_sharded_solve_across_mesh_sizes(prob):
    """Mesh sizes that do and don't divide the subdomain count (padding)."""
    sol1 = FetiSolver(prob, CFG).solve(tol=1e-10)
    n_dev = len(jax.devices())
    for nd in sorted({2, 3, n_dev}):
        if nd > n_dev:
            continue
        sol = FetiSolver(prob, FetiConfig(
            schur=CFG, mesh=make_feti_mesh(nd))).solve(tol=1e-10)
        assert sol.iterations == sol1.iterations
        assert np.max(np.abs(sol.u_global - sol1.u_global)) < 1e-9
