"""FEM substrate tests: meshes, stiffness, loads, decomposition invariants."""
import numpy as np
import pytest

from repro.fem import (
    assemble_dense,
    assemble_scipy_csr,
    decompose_heat_problem,
    fixing_node_regularization,
    kernel_basis,
    load_vector,
    p1_element_stiffness,
    structured_mesh,
)


def test_mesh_2d_counts_and_area():
    mesh = structured_mesh((4, 3))
    assert mesh.n_nodes == 5 * 4
    assert mesh.n_elems == 4 * 3 * 2
    # triangles tile the unit square
    p = mesh.coords[mesh.elems]
    d = np.swapaxes(p[:, 1:, :] - p[:, :1, :], 1, 2)
    area = np.abs(np.linalg.det(d)) / 2
    assert np.isclose(area.sum(), 1.0)


def test_mesh_3d_counts_and_volume():
    mesh = structured_mesh((2, 3, 2))
    assert mesh.n_nodes == 3 * 4 * 3
    assert mesh.n_elems == 2 * 3 * 2 * 6
    p = mesh.coords[mesh.elems]
    d = np.swapaxes(p[:, 1:, :] - p[:, :1, :], 1, 2)
    vol = np.abs(np.linalg.det(d)) / 6
    assert np.isclose(vol.sum(), 1.0)
    assert np.all(vol > 0)


@pytest.mark.parametrize("shape", [(3, 3), (2, 2, 2)])
def test_stiffness_spsd_with_constant_kernel(shape):
    mesh = structured_mesh(shape)
    Ke = p1_element_stiffness(mesh.coords, mesh.elems)
    K = np.asarray(assemble_dense(mesh.n_nodes, mesh.elems, Ke))
    # symmetric
    np.testing.assert_allclose(K, K.T, atol=1e-12)
    # constants in the kernel (pure Neumann Laplace)
    np.testing.assert_allclose(K @ np.ones(mesh.n_nodes), 0.0, atol=1e-10)
    # PSD with exactly one zero eigenvalue
    w = np.linalg.eigvalsh(K)
    assert w[0] > -1e-10
    assert w[0] < 1e-10 < w[1]


def test_assemble_dense_matches_scipy():
    mesh = structured_mesh((4, 2))
    Ke = p1_element_stiffness(mesh.coords, mesh.elems)
    Kd = np.asarray(assemble_dense(mesh.n_nodes, mesh.elems, Ke))
    Ks = assemble_scipy_csr(mesh.n_nodes, mesh.elems, np.asarray(Ke)).toarray()
    np.testing.assert_allclose(Kd, Ks, atol=1e-12)


def test_load_vector_integrates_source():
    mesh = structured_mesh((5, 5))
    f = np.asarray(load_vector(mesh.coords, mesh.elems, mesh.n_nodes, source=3.0))
    assert np.isclose(f.sum(), 3.0)  # integral of the source over unit square


def test_regularization_makes_spd_and_generalized_inverse():
    mesh = structured_mesh((3, 3))
    Ke = p1_element_stiffness(mesh.coords, mesh.elems)
    K = np.asarray(assemble_dense(mesh.n_nodes, mesh.elems, Ke))
    Kreg = fixing_node_regularization(K, fixing_node=4)
    w = np.linalg.eigvalsh(Kreg)
    assert w[0] > 1e-10
    # K Kreg^{-1} K == K  (exact generalized inverse — DESIGN.md §2)
    KpK = K @ np.linalg.solve(Kreg, K)
    np.testing.assert_allclose(KpK, K, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("dim,sub_grid,eps", [
    (2, (2, 2), (3, 3)),
    (2, (3, 2), (2, 4)),
    (3, (2, 2, 2), (2, 2, 2)),
])
def test_decomposition_invariants(dim, sub_grid, eps):
    prob = decompose_heat_problem(dim, sub_grid, eps)
    assert prob.n_subdomains == int(np.prod(sub_grid))
    n_i = prob.subdomains[0].n
    assert n_i == int(np.prod([e + 1 for e in eps]))

    # each multiplier id is used by the right number of subdomain columns
    counts = np.zeros(prob.n_lambda + 1, dtype=int)
    for sd in prob.subdomains:
        used = sd.lambda_ids[: sd.m]
        counts[used] += 1
        # padded tail points at the dummy slot
        assert np.all(sd.lambda_ids[sd.m :] == prob.n_lambda)
        # each real column has exactly one ±1 entry
        col_nnz = (sd.Bt[:, : sd.m] != 0).sum(axis=0)
        assert np.all(col_nnz == 1)
        assert np.all(sd.Bt[:, sd.m :] == 0)
    counts = counts[:-1]
    assert np.all((counts == 1) | (counts == 2))  # Dirichlet rows: 1; gluing: 2

    # gluing rows sum to zero across subdomains: B @ (1 ... 1 stacked u)
    # with u = the *same* global field restricted to each subdomain -> B u = c = 0
    u_glob = np.arange(prob.global_mesh.n_nodes, dtype=float)
    r = np.zeros(prob.n_lambda + 1)
    for sd in prob.subdomains:
        u_i = u_glob[sd.node_gids]
        np.add.at(r, sd.lambda_ids, sd.Bt.T @ u_i)
    gluing = counts == 2
    np.testing.assert_allclose(r[:-1][gluing], 0.0, atol=1e-9)


def test_decomposition_dirichlet_rows_touch_x0_face():
    prob = decompose_heat_problem(2, (2, 1), (2, 2))
    # x=0 face has (Gy+1) = 3 nodes; left subdomains only
    assert len(prob.dirichlet_gids) == 3


def test_kernel_basis_is_unit_norm():
    r = kernel_basis(16)
    assert r.shape == (16, 1)
    assert np.isclose(np.linalg.norm(r), 1.0)
    assert np.all(r > 0)  # the familiar +1/sqrt(n) constant


@pytest.mark.parametrize("dim,k", [(2, 3), (3, 6)])
def test_kernel_basis_elasticity_is_orthonormal(dim, k):
    mesh = structured_mesh((2,) * dim)
    R = kernel_basis(problem="elasticity", coords=mesh.coords)
    assert R.shape == (mesh.n_nodes * dim, k)
    np.testing.assert_allclose(R.T @ R, np.eye(k), atol=1e-12)
