"""Training substrate: optimizer semantics, loss decrease on a learnable
synthetic stream, grad accumulation equivalence, serve steps, data paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenFileDataset, synthetic_batch, write_token_file
from repro.models import ModelConfig, init_model
from repro.train import (
    OptimizerConfig,
    TrainConfig,
    adamw_init,
    adamw_update,
    loss_fn,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.train.optimizer import cosine_lr
from repro.train.serve_step import greedy_generate

TINY = dict(
    name="tiny", family="dense", num_layers=2, d_model=64, d_ff=128,
    vocab_size=61, num_heads=4, num_kv_heads=2, dtype="float32",
    param_dtype="float32",
)


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1.0)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b for a, b in zip(lrs[1:], lrs[2:]))


def test_adamw_moves_against_gradient():
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.ones((4,), jnp.float32)}
    cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=0, weight_decay=0.0,
                          clip_norm=1e9)
    st = adamw_init(params, cfg)
    new, st, metrics = adamw_update(params, grads, st, cfg)
    assert np.all(np.asarray(new["w"]) < 1.0)
    assert metrics["grad_norm"] == pytest.approx(2.0)


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((8,), jnp.float32)}
    cfg = OptimizerConfig(moment_dtype="bfloat16")
    st = adamw_init(params, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.full((8,), 0.5, jnp.float32)}
    new, st, _ = adamw_update(params, grads, st, cfg)
    assert jnp.isfinite(new["w"]).all()


def test_loss_decreases_on_learnable_stream():
    cfg = ModelConfig(**TINY)
    tcfg = TrainConfig(optimizer=OptimizerConfig(learning_rate=3e-3,
                                                 warmup_steps=5,
                                                 total_steps=100),
                       remat=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, tcfg.optimizer)
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    for i in range(30):
        batch = synthetic_batch(cfg, 8, 32, seed=1, step=i)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_grad_accum_matches_full_batch():
    cfg = ModelConfig(**TINY)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(cfg, 8, 16, seed=2)
    t1 = TrainConfig(remat=False, grad_accum=1, z_loss_coef=0.0)
    t4 = TrainConfig(remat=False, grad_accum=4, z_loss_coef=0.0)
    g1 = jax.grad(lambda p: loss_fn(p, cfg, batch, t1)[0])(params)

    # accumulate manually over the same microbatches used by the step
    def micro(b, i):
        return jax.tree.map(lambda x: x.reshape((4, 2) + x.shape[1:])[i], b)

    gs = [jax.grad(lambda p: loss_fn(p, cfg, micro(batch, i), t4)[0])(params)
          for i in range(4)]
    gacc = jax.tree.map(lambda *x: sum(x) / 4, *gs)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gacc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_greedy_generate_deterministic():
    cfg = ModelConfig(**TINY)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out1, _ = greedy_generate(params, cfg, prompt, steps=6)
    out2, _ = greedy_generate(params, cfg, prompt, steps=6)
    assert out1.shape == (1, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_serve_steps_shapes():
    cfg = ModelConfig(**TINY)
    params = init_model(jax.random.PRNGKey(0), cfg)
    from repro.models import init_cache

    cache = init_cache(cfg, 2, 16)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    logits, cache = prefill(params, {"tokens": jnp.zeros((2, 8), jnp.int32)},
                            cache)
    assert logits.shape == (2, cfg.vocab_size)
    logits, cache = decode(params, jnp.zeros((2, 1), jnp.int32), cache,
                           jnp.asarray(8, jnp.int32))
    assert logits.shape == (2, cfg.vocab_size)


def test_token_file_roundtrip(tmp_path):
    path = str(tmp_path / "toks.bin")
    toks = np.arange(1000) % 50000  # forces uint32
    write_token_file(path, toks)
    ds = TokenFileDataset(path, seq_len=16, batch_size=4)
    batch = next(iter(ds))
    assert batch["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(batch["labels"][:, :-1], batch["tokens"][:, 1:])


def test_token_file_host_sharding_disjoint(tmp_path):
    path = str(tmp_path / "toks.bin")
    write_token_file(path, np.arange(10_000) % 100)
    seen = []
    for host in range(2):
        ds = TokenFileDataset(path, seq_len=16, batch_size=2, host_id=host,
                              num_hosts=2, seed=3)
        b = next(iter(ds))
        seen.append(np.asarray(b["tokens"]))
    assert not np.array_equal(seen[0], seen[1])
