"""Oracle-backed multi-RHS solve tier (ISSUE 6).

``FetiSolver.solve_many`` streams stacked load cases through one
block-PCPG against a cluster preprocessed ONCE; every test here checks it
against per-column undecomposed scipy solves (``reference_solutions``), the
per-column stopping semantics, or the single-RHS path it must degenerate to.

The module runs unchanged under ``REPRO_STORAGE=dense`` and
``REPRO_STORAGE=packed`` (storage is left to the env default, as in the CI
packed lane), and covers heat + elasticity, 2D + 3D, lumped + dirichlet.
Sharded tests are additionally marked ``multidevice`` and auto-skip below
2 devices (tests/conftest.py).
"""
import numpy as np
import pytest

from repro.core import SchurAssemblyConfig
from repro.fem import decompose_problem
from repro.feti import FetiConfig, FetiSolver

pytestmark = pytest.mark.multirhs

multidevice = pytest.mark.multidevice

CFG = SchurAssemblyConfig(block_size=8, rhs_block_size=8)

# oracle agreement bar: |u - u_ref| <= ORACLE_RTOL * max|u_ref| per column
ORACLE_RTOL = 1e-8


@pytest.fixture(scope="module", params=["heat", "elasticity"])
def prob2d(request):
    return decompose_problem(request.param, 2, (2, 2), (3, 3))


@pytest.fixture(scope="module", params=["heat", "elasticity"])
def prob3d(request):
    return decompose_problem(request.param, 3, (2, 2, 1), (2, 2, 2))


def _check_oracle(prob, solm, cases):
    refs = prob.reference_solutions(cases)
    scale = np.abs(refs).max()
    assert bool(solm.converged.all())
    np.testing.assert_allclose(
        solm.u_global, refs, atol=ORACLE_RTOL * scale)


# --------------------------------------------------------------------------
# oracle agreement: solve_many == per-column scipy global solves
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_solve_many_matches_oracle_2d(prob2d, mode):
    cases = prob2d.load_cases(4, kind="mixed", seed=0)
    solver = FetiSolver(prob2d, FetiConfig(schur=CFG, mode=mode))
    solm = solver.solve_many(cases, tol=1e-10)
    _check_oracle(prob2d, solm, cases)
    # the whole point: one preprocess, streamed batches — a second batch
    # through the same solver must reuse the cached state and stay right
    cases2 = prob2d.load_cases(4, kind="random", seed=7)
    _check_oracle(prob2d, solver.solve_many(cases2, tol=1e-10), cases2)


def test_solve_many_matches_oracle_3d(prob3d):
    cases = prob3d.load_cases(3, kind="mixed", seed=1)
    solm = FetiSolver(prob3d, CFG).solve_many(cases, tol=1e-10)
    _check_oracle(prob3d, solm, cases)


@pytest.mark.dirichlet
def test_solve_many_dirichlet_preconditioner(prob2d):
    cases = prob2d.load_cases(3, kind="mixed", seed=2)
    solm = FetiSolver(prob2d, FetiConfig(
        schur=CFG, preconditioner="dirichlet")).solve_many(
        cases, tol=1e-10)
    _check_oracle(prob2d, solm, cases)


def test_solve_many_sweep_cases(prob2d):
    """A load sweep (scaled base loads): solutions are the scaled base
    solution, and relative per-column stopping converges them together."""
    cases = prob2d.load_cases(3, kind="sweep")
    solm = FetiSolver(prob2d, CFG).solve_many(cases, tol=1e-10)
    _check_oracle(prob2d, solm, cases)
    base = prob2d.reference_solution()
    for j, s in enumerate((1.0, 2.0, 3.0)):
        np.testing.assert_allclose(
            solm.u_global[j], s * base, atol=1e-8 * np.abs(base).max() * s)


# --------------------------------------------------------------------------
# per-column stopping semantics
# --------------------------------------------------------------------------


def test_per_column_stopping_freezes_converged_columns(prob2d):
    """Mixed batch: the zero-load column converges at iteration 0, live
    columns keep iterating — counts must differ and the block runs only
    max-over-columns iterations."""
    cases = prob2d.load_cases(4, kind="mixed", seed=3)  # col 1 is zero load
    solm = FetiSolver(prob2d, CFG).solve_many(cases, tol=1e-10)
    assert solm.iterations[1] == 0  # zero load: converged before the loop
    assert (solm.iterations[[0, 2, 3]] > 0).all()
    assert len(np.unique(solm.iterations)) >= 2
    assert solm.block_iterations == int(solm.iterations.max())
    assert bool(solm.converged.all())
    # the frozen zero column's solution is exactly the zero solution
    np.testing.assert_allclose(
        solm.u_global[1], 0.0,
        atol=ORACLE_RTOL * np.abs(solm.u_global).max())


def test_columns_are_independent(prob2d):
    """A column's trajectory must not depend on its batch neighbours:
    same column content + same batch shape => bit-identical results."""
    base = prob2d.load_stack()
    rng = np.random.default_rng(4)
    other = rng.standard_normal(base.shape)
    solver = FetiSolver(prob2d, CFG)
    a = solver.solve_many(np.stack([base, np.zeros_like(base)]), tol=1e-10)
    b = solver.solve_many(np.stack([base, other]), tol=1e-10)
    assert np.array_equal(a.u_global[0], b.u_global[0])
    assert np.array_equal(a.lam[0], b.lam[0])
    assert a.iterations[0] == b.iterations[0]


def test_single_column_solve_many_bit_identical_to_solve(prob2d):
    """A 1-column batch dispatches through the exact single-RHS program."""
    solver = FetiSolver(prob2d, CFG)
    sol = solver.solve(tol=1e-10)
    solm = solver.solve_many(prob2d.load_stack(), tol=1e-10)
    assert solm.n_rhs == solm.n_rhs_padded == 1
    assert np.array_equal(solm.u_global[0], sol.u_global)
    assert np.array_equal(solm.u[0], sol.u)
    assert np.array_equal(solm.lam[0], sol.lam)
    assert np.array_equal(solm.alpha[0], sol.alpha)
    assert solm.iterations[0] == sol.iterations
    assert solm.residuals[0] == sol.residual


# --------------------------------------------------------------------------
# batching mechanics: ragged batches, padding, validation
# --------------------------------------------------------------------------


def test_ragged_batch_rhs_unit_padding(prob2d):
    """n_rhs=3 with rhs_unit=4 pads with a zero column internally and
    strips it from the result; values match the unpadded batch."""
    cases = prob2d.load_cases(3, kind="mixed", seed=5)
    solver = FetiSolver(prob2d, CFG)
    ragged = solver.solve_many(cases, tol=1e-10, rhs_unit=4)
    assert ragged.n_rhs == 3 and ragged.n_rhs_padded == 4
    assert ragged.u_global.shape[0] == 3
    assert ragged.iterations.shape == (3,)
    _check_oracle(prob2d, ragged, cases)
    # padding columns are zero loads: they converge at iteration 0, so
    # they cannot change any live column (column independence above) —
    # the padded batch agrees with the exact batch to solver accuracy
    exact = solver.solve_many(cases, tol=1e-10)
    scale = np.abs(exact.u_global).max()
    np.testing.assert_allclose(ragged.u_global, exact.u_global,
                               atol=1e-9 * scale)


def test_solve_many_input_validation(prob2d):
    solver = FetiSolver(prob2d, CFG)
    good = prob2d.load_cases(2)
    with pytest.raises(ValueError, match="loads must be"):
        solver.solve_many(good[:, :, :-1])
    with pytest.raises(ValueError, match="rhs_unit"):
        solver.solve_many(good, rhs_unit=0)


def test_load_cases_generators(prob2d):
    S, n = prob2d.n_subdomains, prob2d.subdomains[0].n
    sweep = prob2d.load_cases(3, kind="sweep")
    assert sweep.shape == (3, S, n)
    np.testing.assert_allclose(sweep[1], 2.0 * sweep[0])
    mixed = prob2d.load_cases(3, kind="mixed", seed=0)
    np.testing.assert_array_equal(mixed[0], prob2d.load_stack())
    assert not mixed[1].any()
    rand = prob2d.load_cases(3, kind="random", seed=0)
    assert rand.shape == (3, S, n)
    with pytest.raises(ValueError, match="kind"):
        prob2d.load_cases(2, kind="bogus")


# --------------------------------------------------------------------------
# distributed: sharded solve_many vs single-device
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_feti_mesh

    return make_feti_mesh()


@multidevice
def test_sharded_solve_many_matches_single_device(prob2d, mesh):
    """Same batch through the shard_map deployment: the heat solutions
    agree to ~1e-14 (the sharded program is a reordered reduction of the
    same arithmetic); elasticity columns may stop one iteration apart
    near the threshold, so they agree at the achieved-residual level."""
    cases = prob2d.load_cases(4, kind="mixed", seed=6)
    ref = FetiSolver(prob2d, CFG).solve_many(cases, tol=1e-10)
    sh = FetiSolver(prob2d, FetiConfig(
        schur=CFG, mesh=mesh)).solve_many(cases, tol=1e-10)
    assert bool(sh.converged.all())
    du = np.abs(sh.u_global - ref.u_global).max()
    bar = 5e-13 if prob2d.problem == "heat" else 1e-10
    assert du <= bar, f"sharded drifted from single-device: {du:.2e}"
    assert np.abs(sh.iterations - ref.iterations).max() <= 1
    _check_oracle(prob2d, sh, cases)


@multidevice
def test_sharded_ragged_batch_roundtrip(prob2d, mesh):
    """Ragged n_rhs (5, not divisible by rhs_unit=4 or the device count)
    pads to 8 columns device-side and round-trips to exactly 5 results."""
    cases = prob2d.load_cases(5, kind="mixed", seed=8)
    sh = FetiSolver(prob2d, FetiConfig(schur=CFG, mesh=mesh)).solve_many(
        cases, tol=1e-10, rhs_unit=4)
    assert sh.n_rhs == 5 and sh.n_rhs_padded == 8
    assert sh.u_global.shape[0] == 5 and sh.lam.shape[0] == 5
    _check_oracle(prob2d, sh, cases)


@multidevice
def test_sharded_single_column_matches_sharded_solve(prob2d, mesh):
    solver = FetiSolver(prob2d, FetiConfig(schur=CFG, mesh=mesh))
    sol = solver.solve(tol=1e-10)
    solm = solver.solve_many(prob2d.load_stack(), tol=1e-10)
    assert np.array_equal(solm.u_global[0], sol.u_global)
    assert solm.iterations[0] == sol.iterations
