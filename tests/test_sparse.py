"""Sparse substrate tests: ordering, symbolic block fill, blocked Cholesky."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fem import assemble_dense, p1_element_stiffness, structured_mesh
from repro.fem.regularization import fixing_node_regularization
from repro.sparse import (
    block_cholesky,
    block_cholesky_flops,
    block_pattern,
    block_symbolic_cholesky,
    matrix_pattern_from_elems,
    nested_dissection_order,
    rcm_order,
)
from repro.testing import random_banded_spd


@pytest.mark.parametrize("shape", [(5, 5), (9, 9), (4, 6), (3, 3, 3), (5, 4, 3)])
def test_nd_order_is_permutation(shape):
    perm = nested_dissection_order(shape)
    n = int(np.prod(shape))
    assert sorted(perm.tolist()) == list(range(n))


@pytest.mark.parametrize("shape", [(5, 5), (3, 4, 3)])
def test_rcm_order_is_permutation(shape):
    perm = rcm_order(shape)
    n = int(np.prod(shape))
    assert sorted(perm.tolist()) == list(range(n))


def _subdomain_K(shape):
    mesh = structured_mesh(tuple(s - 1 for s in shape))  # shape = node grid
    Ke = p1_element_stiffness(mesh.coords, mesh.elems)
    K = np.asarray(assemble_dense(mesh.n_nodes, mesh.elems, Ke))
    return mesh, fixing_node_regularization(K, fixing_node=0)


def test_nd_reduces_fill_vs_natural():
    """Scalar-granularity fill: ND must beat the natural (banded) order on a
    grid large enough for the separator structure to pay off."""
    shape = (17, 17)
    mesh, K = _subdomain_K(shape)
    pat = matrix_pattern_from_elems(K.shape[0], mesh.elems)

    def fill(perm):
        p = pat[perm][:, perm]
        return block_symbolic_cholesky(block_pattern(p, 1)).sum()

    natural = fill(np.arange(K.shape[0]))
    nd = fill(nested_dissection_order(shape))
    assert nd < natural


def test_symbolic_fill_covers_numeric_fill():
    """Every numerically nonzero block of L must be in the symbolic mask."""
    shape = (7, 7)
    mesh, K = _subdomain_K(shape)
    perm = nested_dissection_order(shape)
    Kp = K[perm][:, perm]
    bs = 8
    pat = matrix_pattern_from_elems(K.shape[0], mesh.elems)[perm][:, perm]
    mask = block_symbolic_cholesky(block_pattern(pat, bs))
    L = np.linalg.cholesky(Kp)
    nb = mask.shape[0]
    for i in range(nb):
        for j in range(i + 1):
            i0, i1 = i * bs, min((i + 1) * bs, L.shape[0])
            j0, j1 = j * bs, min((j + 1) * bs, L.shape[0])
            if np.any(np.abs(L[i0:i1, j0:j1]) > 1e-12):
                assert mask[i, j], f"numeric nnz outside symbolic mask at {(i, j)}"


@pytest.mark.parametrize("n,bs", [(32, 8), (50, 16), (64, 64), (33, 7)])
def test_block_cholesky_dense_matches_lapack(n, bs):
    rng = np.random.default_rng(0)
    K = random_banded_spd(n, min(n - 1, 12), rng)
    L = np.asarray(block_cholesky(jnp.asarray(K), bs))
    want = np.linalg.cholesky(K)
    np.testing.assert_allclose(L, want, rtol=1e-9, atol=1e-9)


def test_block_cholesky_masked_matches_dense():
    shape = (7, 7)
    mesh, K = _subdomain_K(shape)
    perm = nested_dissection_order(shape)
    Kp = K[perm][:, perm]
    bs = 8
    pat = matrix_pattern_from_elems(K.shape[0], mesh.elems)[perm][:, perm]
    mask = block_symbolic_cholesky(block_pattern(pat, bs))
    L = np.asarray(block_cholesky(jnp.asarray(Kp), bs, mask=mask))
    want = np.linalg.cholesky(Kp)
    np.testing.assert_allclose(L, want, rtol=1e-8, atol=1e-8)
    # masked flop model <= dense flop model
    assert block_cholesky_flops(Kp.shape[0], bs, mask) <= block_cholesky_flops(
        Kp.shape[0], bs
    )


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 48), bs=st.integers(2, 16), seed=st.integers(0, 2**31 - 1))
def test_property_block_cholesky(n, bs, seed):
    rng = np.random.default_rng(seed)
    K = random_banded_spd(n, min(n - 1, 8), rng)
    L = np.asarray(block_cholesky(jnp.asarray(K), bs))
    np.testing.assert_allclose(L @ L.T, K, rtol=1e-8, atol=1e-8)
    assert np.allclose(L, np.tril(L))
