"""Launch-layer tests: roofline HLO parsing (incl. while-loop trip-count
correction), the analytic FLOP/byte model, shape-grid rules."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.analytic import lm_cell_counts
from repro.launch.roofline import (
    HW,
    collective_stats_trip_corrected,
    parse_collective_bytes,
    roofline_terms,
)
from repro.launch.shapes import SHAPES, applicable_shapes, input_specs

FAKE_HLO = """\
HloModule jit_f, is_scheduled=true

%cond.1 (arg: (s32[], f32[64,64])) -> pred[] {
  %arg = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body.1 (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg = (s32[], f32[64,64]) parameter(0)
  %x = f32[64,64] get-tuple-element(%arg), index=1
  %ag = f32[64,64]{1,0} all-gather(%x), dimensions={0}
  %i = s32[] get-tuple-element(%arg), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%ip, %ag)
}

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64] parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%p0), to_apply=%add.0
  %init = (s32[], f32[64,64]) tuple(s32[] constant(0), %ar)
  %w = (s32[], f32[64,64]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[64,64] get-tuple-element(%w), index=1
}
"""


def test_parse_collective_bytes_flat():
    st = parse_collective_bytes(FAKE_HLO)
    assert st.count_by_op["all-gather"] == 1
    assert st.count_by_op["all-reduce"] == 1
    assert st.bytes_by_op["all-gather"] == 64 * 64 * 4
    assert st.bytes_by_op["all-reduce"] == 64 * 64 * 4


def test_trip_corrected_multiplies_loop_bodies():
    st = collective_stats_trip_corrected(FAKE_HLO)
    # the all-gather sits in a 10-trip while body; the all-reduce is direct
    assert st.count_by_op["all-gather"] == 10
    assert st.bytes_by_op["all-gather"] == 10 * 64 * 64 * 4
    assert st.count_by_op["all-reduce"] == 1


def test_trip_corrected_on_real_compiled_scan():
    def body(c, _):
        return jnp.tanh(c @ c), None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ).compile().as_text()
    # no collectives on 1 device, but the parser must not crash and the
    # while/cond structure must be discovered
    st = collective_stats_trip_corrected(txt)
    assert st.total_bytes == 0


def test_roofline_terms_dominance():
    from repro.launch.roofline import CollectiveStats

    coll = CollectiveStats(bytes_by_op={"all-reduce": int(50e9)},
                           count_by_op={"all-reduce": 1})
    r = roofline_terms({"flops": 197e12 * 0.1, "bytes accessed": 819e9 * 0.2},
                       coll, chips=256, model_flops=None)
    assert r.compute_s == pytest.approx(0.1)
    assert r.memory_s == pytest.approx(0.2)
    assert r.collective_s == pytest.approx(1.0)
    assert r.dominant == "collective"


# ------------------------------------------------------------- analytic ----
def _counts(arch, shape, **kw):
    cfg = get_config(arch)
    args = dict(chips=256, tp=16, grad_accum=1, remat=True,
                moment_bytes=4, accum_bytes=4)
    args.update(kw)
    if "cfg_override" in args:
        cfg = args.pop("cfg_override")
    return cfg, lm_cell_counts(cfg, SHAPES[shape], **args)


def test_analytic_skip_masked_halves_attention():
    _, full = _counts("granite-3-8b", "prefill_32k")
    _, skip = _counts("granite-3-8b", "prefill_32k", skip_masked=True)
    ratio = skip.notes["attention"] / full.notes["attention"]
    assert 0.45 < ratio < 0.56  # ~ (n+1)/2n of chunk pairs


def test_analytic_sort_moe_removes_dispatch_flops():
    import dataclasses

    cfg = get_config("deepseek-v2-236b")
    gshard = lm_cell_counts(cfg, SHAPES["prefill_32k"], chips=256, tp=16,
                            grad_accum=1, remat=False, moment_bytes=4,
                            accum_bytes=4)
    sort = lm_cell_counts(dataclasses.replace(cfg, moe_impl="sort"),
                          SHAPES["prefill_32k"], chips=256, tp=16,
                          grad_accum=1, remat=False, moment_bytes=4,
                          accum_bytes=4)
    assert sort.notes["moe"] < 0.01 * gshard.notes["moe"]


def test_analytic_train_counts_remat_pass():
    _, c = _counts("granite-3-8b", "train_4k", remat=True)
    _, c_no = _counts("granite-3-8b", "train_4k", remat=False)
    assert c.notes["fwd_passes"] == 4.0 and c_no.notes["fwd_passes"] == 3.0
    assert c.flops_global == pytest.approx(c_no.flops_global * 4 / 3)


def test_analytic_model_flops_is_6nd_for_train():
    cfg, c = _counts("granite-3-8b", "train_4k")
    tokens = 256 * 4096
    assert c.model_flops == pytest.approx(
        6.0 * cfg.active_param_count() * tokens)


def test_analytic_decode_memory_includes_cache():
    cfg, c = _counts("mistral-large-123b", "decode_32k")
    assert c.notes["cache_stream_dev"] > 0
    # decode must be memory-dominated in the model
    assert c.hbm_bytes_per_dev / HW["hbm_bw"] > c.flops_per_dev / HW["peak_flops"]


# ---------------------------------------------------------------- shapes ----
def test_applicable_shapes_rules():
    assert applicable_shapes(get_config("hubert-xlarge")) == \
        ["train_4k", "prefill_32k"]
    assert "long_500k" in applicable_shapes(get_config("rwkv6-1.6b"))
    assert "long_500k" not in applicable_shapes(get_config("granite-3-8b"))


def test_input_specs_are_abstract():
    cfg = get_config("qwen2-vl-2b")
    specs = input_specs(cfg, SHAPES["train_4k"])
    assert specs["tokens"].shape == (256, 4096)
    assert specs["positions"].shape == (256, 4096, 3)
    for v in specs.values():
        assert isinstance(v, jax.ShapeDtypeStruct)


def test_audio_specs_have_features():
    cfg = get_config("hubert-xlarge")
    specs = input_specs(cfg, SHAPES["prefill_32k"])
    assert specs["features"].shape == (32, 32768, 1280)
