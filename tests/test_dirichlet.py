"""Dirichlet preconditioner: the primal boundary/interior Schur pipeline.

The acceptance bar of the subsystem (ISSUE 5):

  * boundary ∪ interior partitions the local DOFs (node-blocked for
    vector problems), with B̃ᵀ supported entirely on the boundary,
  * S_b matches a dense scipy Schur-complement reference ≤ 1e-10 for heat
    AND elasticity, in dense and packed interior-factor storage,
  * S_b assembled from the regularized K is SPD; the production
    (unregularized, own-boundary-restricted) S_b is SPSD with exact zero
    spurious rows,
  * dirichlet-preconditioned PCPG needs STRICTLY fewer iterations than
    lumped on the elasticity oracle cases and matches the undecomposed
    scipy solution ≤ 1e-8 (2D and 3D, dense and packed),
  * the sharded dirichlet solve reproduces the single-device one
    (multidevice marker → CI multidevice lane),
  * the stage goes through core.schur.make_assembler and is covered by
    the autotuner search space and plan cache (stage="dirichlet" key).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SchurAssemblyConfig
from repro.fem import decompose_problem
from repro.feti import FetiConfig, FetiSolver
from repro.feti.assembly import preprocess_cluster
from repro.feti.dirichlet import (
    assemble_dirichlet_schur,
    boundary_interior_split,
    own_boundary_masks,
    restrict_own_boundary,
)
from repro.feti.operator import dirichlet_preconditioner, gather_local

pytestmark = pytest.mark.dirichlet

CFG = SchurAssemblyConfig(block_size=8, rhs_block_size=8, storage="dense")
CFG_P = SchurAssemblyConfig(block_size=8, rhs_block_size=8, storage="packed")


@pytest.fixture(scope="module", params=["heat", "elasticity"])
def prob2d(request):
    return decompose_problem(request.param, 2, (2, 2), (4, 4))


@pytest.fixture(scope="module", params=["heat", "elasticity"])
def prob3d(request):
    return decompose_problem(request.param, 3, (2, 2, 1), (2, 2, 2))


# both workloads × both dimensions for the symbolic/S_b property tests
@pytest.fixture(scope="module",
                params=[("heat", 2), ("elasticity", 2),
                        ("heat", 3), ("elasticity", 3)],
                ids=lambda p: f"{p[0]}-{p[1]}d")
def prob(request):
    problem, dim = request.param
    if dim == 2:
        return decompose_problem(problem, 2, (2, 2), (4, 4))
    return decompose_problem(problem, 3, (2, 2, 1), (2, 2, 2))


def _oracle_error(prob, sol):
    u_ref = prob.reference_solution()
    return np.max(np.abs(sol.u_global - u_ref)) / np.abs(u_ref).max()


def _schur_ref(K, keep):
    """Dense scipy-style Schur complement of K onto the ``keep`` DOFs."""
    elim = np.setdiff1d(np.arange(K.shape[0]), keep)
    Kbb = K[np.ix_(keep, keep)]
    Kbi = K[np.ix_(keep, elim)]
    Kii = K[np.ix_(elim, elim)]
    return Kbb - Kbi @ np.linalg.solve(Kii, Kbi.T)


# --------------------------------------------------------------------------
# the boundary/interior split
# --------------------------------------------------------------------------


def test_property_split_partitions_dofs(prob):
    """boundary ∪ interior = all DOFs, disjoint, node-blocked, and B̃ᵀ has
    no interior rows (the restriction to Btb loses nothing)."""
    split = boundary_interior_split(prob)
    n = prob.subdomains[0].n
    both = np.concatenate([split.interior, split.boundary])
    assert len(both) == n and len(np.unique(both)) == n
    assert split.n_i + split.n_b == n
    split.validate_partition()
    ndpn = prob.ndof_per_node
    if ndpn > 1:  # all components of a node land on the same side
        bset = np.zeros(n, bool)
        bset[split.boundary] = True
        per_node = bset.reshape(-1, ndpn)
        assert np.all(per_node.all(axis=1) == per_node.any(axis=1))
    for sd in prob.subdomains:
        assert np.all(sd.Bt[split.interior] == 0)


def test_split_orderings_and_errors():
    prob = decompose_problem("heat", 2, (2, 2), (4, 4))
    for ordering in ("nd", "rcm", "natural"):
        split = boundary_interior_split(prob, ordering=ordering)
        split.validate_partition()
    with pytest.raises(ValueError):
        boundary_interior_split(prob, ordering="bogus")


def test_own_boundary_masks_flag_exactly_the_unglued():
    prob = decompose_problem("elasticity", 2, (2, 2), (4, 4))
    split = boundary_interior_split(prob)
    Z = own_boundary_masks(prob, split)
    assert Z.shape == (prob.n_subdomains, split.n_b)
    for i, sd in enumerate(prob.subdomains):
        own = np.zeros(sd.n, bool)
        own[sd.b_rows[: sd.m]] = True
        own = np.repeat(own.reshape(-1, 2).any(axis=1), 2)
        np.testing.assert_array_equal(Z[i] == 1.0, ~own[split.boundary])
        # a (2, 2) grid has outer faces on every subdomain: some spurious
        assert Z[i].sum() > 0


# --------------------------------------------------------------------------
# S_b against the dense scipy reference
# --------------------------------------------------------------------------


def test_union_schur_matches_scipy_reference(prob):
    """The shared (union-boundary) S_b from the sparse TRSM/SYRK pipeline
    == the dense reference Schur complement, ≤ 1e-10, per subdomain."""
    Sb, _, split = assemble_dirichlet_schur(prob, CFG, restrict=False)
    Sb = np.asarray(Sb)
    for i, sd in enumerate(prob.subdomains):
        ref = _schur_ref(sd.K, split.boundary)
        err = np.abs(Sb[i] - ref).max() / np.abs(ref).max()
        assert err <= 1e-10, f"subdomain {i}: {err:.2e}"


def test_restricted_schur_matches_per_subdomain_reference(prob):
    """After the own-boundary restriction, each subdomain's S_b equals the
    Schur complement of K onto exactly ITS glued DOFs (embedded in the
    shared frame with exact zero spurious rows/columns)."""
    Sb, _, split = assemble_dirichlet_schur(prob, CFG, restrict=True)
    Sb = np.asarray(Sb)
    pos = {g: j for j, g in enumerate(split.boundary)}
    ndpn = prob.ndof_per_node
    for i, sd in enumerate(prob.subdomains):
        own = np.zeros(sd.n, bool)
        own[sd.b_rows[: sd.m]] = True
        if ndpn > 1:
            own = np.repeat(own.reshape(-1, ndpn).any(axis=1), ndpn)
        g = np.flatnonzero(own)
        ref = _schur_ref(sd.K, g)
        idx = np.asarray([pos[x] for x in g])
        err = np.abs(Sb[i][np.ix_(idx, idx)] - ref).max() / np.abs(ref).max()
        assert err <= 1e-10, f"subdomain {i}: {err:.2e}"
        spur = np.setdiff1d(np.arange(split.n_b), idx)
        assert np.abs(Sb[i][spur]).max() <= 1e-10 * np.abs(ref).max()
        assert np.abs(Sb[i][:, spur]).max() <= 1e-10 * np.abs(ref).max()


def test_schur_spd_after_regularization(prob2d):
    """S_b assembled from the fixing-DOF-regularized K is SPD; the
    production S_b (unregularized) is SPSD with kernel dim == the
    subdomain kernel dim (rigid modes restricted to the boundary)."""
    Sb_reg, _, _ = assemble_dirichlet_schur(prob2d, CFG, regularized=True,
                                            restrict=False)
    for S in np.asarray(Sb_reg):
        w = np.linalg.eigvalsh(S)
        assert w[0] > 0, f"min eig {w[0]:.2e}"
    Sb, _, _ = assemble_dirichlet_schur(prob2d, CFG, restrict=False)
    k = prob2d.kernel_dim
    for S in np.asarray(Sb):
        w = np.linalg.eigvalsh(S)
        scale = w[-1]
        assert w[0] > -1e-10 * scale  # SPSD
        assert w[k - 1] < 1e-9 * scale < w[k]  # exactly k zero modes


def test_packed_interior_factor_matches_dense(prob2d):
    """storage="packed" runs the interior factorization + TRSM in the
    packed block-sparse layout; the assembled S_b must agree ≤ 1e-10."""
    Sb_d, _, _ = assemble_dirichlet_schur(prob2d, CFG)
    Sb_p, _, _ = assemble_dirichlet_schur(prob2d, CFG_P)
    scale = np.abs(np.asarray(Sb_d)).max()
    np.testing.assert_allclose(np.asarray(Sb_p), np.asarray(Sb_d),
                               rtol=0, atol=1e-10 * scale)


def test_restriction_is_noop_for_all_glued_boundary():
    """z = 0 (no spurious DOFs) must leave S_b bit-for-bit unchanged."""
    rng = np.random.default_rng(0)
    A = rng.standard_normal((10, 10))
    S = jnp.asarray(A @ A.T + 10 * np.eye(10))
    out = restrict_own_boundary(S, jnp.zeros(10))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(S))


# --------------------------------------------------------------------------
# preprocessing integration (ClusterState)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["dense", "packed"])
def test_preprocess_carries_dirichlet_state(prob2d, storage):
    st = preprocess_cluster(prob2d, FetiConfig(
        schur=CFG, storage=storage, preconditioner="dirichlet"))
    split = st.split
    assert st.Sb.shape == (prob2d.n_subdomains, split.n_b, split.n_b)
    assert st.Btb.shape[1] == split.n_b
    assert st.dirichlet_cfg.storage == storage
    assert st.dirichlet_env is not None and st.dirichlet_mask is not None
    by = st.device_bytes()
    assert by["Sb"] > 0 and by["Btb"] > 0
    assert by["total"] >= by["Sb"] + by["Btb"]
    # the state's S_b == the one-shot assembly (same pipeline inlined)
    cfg_s = SchurAssemblyConfig(block_size=8, rhs_block_size=8,
                                storage=storage)
    Sb_ref, Btb_ref, _ = assemble_dirichlet_schur(prob2d, cfg_s)
    np.testing.assert_allclose(np.asarray(st.Sb), np.asarray(Sb_ref),
                               rtol=0, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(st.Btb), np.asarray(Btb_ref))


def test_preprocess_without_dirichlet_keeps_state_lean(prob2d):
    st = preprocess_cluster(prob2d, CFG)
    assert st.Sb is None and st.Btb is None and st.split is None
    assert st.device_bytes()["Sb"] == 0


def test_implicit_mode_still_assembles_dirichlet(prob2d):
    """mode="implicit" skips F but the dirichlet stage still runs (the
    preconditioner is orthogonal to the dual-operator representation)."""
    st = preprocess_cluster(prob2d, FetiConfig(
        schur=CFG, mode="implicit", preconditioner="dirichlet"))
    assert st.F is None and st.Sb is not None


def test_solver_guards_state_without_dirichlet(prob2d):
    solver = FetiSolver(prob2d, FetiConfig(schur=CFG))
    solver.preprocess()
    solver.preconditioner = "dirichlet"  # stale state: no Sb
    with pytest.raises(ValueError, match="dirichlet"):
        solver.solve(tol=1e-9)
    with pytest.raises(ValueError, match="preconditioner"):
        FetiSolver(prob2d, FetiConfig(schur=CFG, preconditioner="bogus"))


def test_preconditioner_apply_matches_explicit_form(prob2d):
    """dirichlet_preconditioner == the hand-written gather → Btb lift →
    S_b GEMV → restrict → scatter sandwich."""
    st = preprocess_cluster(prob2d, FetiConfig(
        schur=CFG, preconditioner="dirichlet"))
    nl = prob2d.n_lambda
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal(nl))
    out = dirichlet_preconditioner(st.Sb, st.Btb, st.lambda_ids, nl, w)
    p = gather_local(w, st.lambda_ids)
    v = jnp.einsum("sbm,sm->sb", st.Btb, p)
    v = jnp.einsum("sab,sb->sa", st.Sb, v)
    q = jnp.einsum("sbm,sb->sm", st.Btb, v)
    ref = jnp.zeros((nl + 1,), q.dtype).at[st.lambda_ids].add(q)[:-1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-13)


# --------------------------------------------------------------------------
# the oracle: dirichlet-PCPG converges, beats lumped, matches scipy
# --------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["dense", "packed"])
@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_dirichlet_2d_matches_oracle(prob2d, mode, storage):
    sol = FetiSolver(prob2d, FetiConfig(
        schur=CFG, mode=mode, preconditioner="dirichlet",
        storage=storage)).solve(tol=1e-10)
    assert sol.converged
    assert _oracle_error(prob2d, sol) <= 1e-8


@pytest.mark.elasticity
@pytest.mark.parametrize("storage", ["dense", "packed"])
def test_dirichlet_3d_matches_oracle(prob3d, storage):
    sol = FetiSolver(prob3d, FetiConfig(
        schur=CFG, preconditioner="dirichlet",
        storage=storage)).solve(tol=1e-10)
    assert sol.converged
    assert _oracle_error(prob3d, sol) <= 1e-8


@pytest.mark.elasticity
@pytest.mark.parametrize("dim,grid,eps", [
    (2, (2, 2), (8, 8)),
    (3, (2, 2, 1), (2, 2, 2)),
])
def test_dirichlet_strictly_beats_lumped_on_elasticity(dim, grid, eps):
    """The reason the stage exists: strictly fewer PCPG iterations than
    lumped on the conditioned elasticity oracle cases (2D and 3D), both
    matching the undecomposed solve."""
    prob = decompose_problem("elasticity", dim, grid, eps)
    sol_l = FetiSolver(prob, CFG).solve(tol=1e-10)
    sol_d = FetiSolver(prob, FetiConfig(
        schur=CFG, preconditioner="dirichlet")).solve(tol=1e-10)
    assert sol_l.converged and sol_d.converged
    assert sol_d.iterations < sol_l.iterations
    assert _oracle_error(prob, sol_d) <= 1e-8


def test_dirichlet_beats_lumped_on_heat():
    prob = decompose_problem("heat", 2, (2, 2), (8, 8))
    sol_l = FetiSolver(prob, CFG).solve(tol=1e-10)
    sol_d = FetiSolver(prob, FetiConfig(
        schur=CFG, preconditioner="dirichlet")).solve(tol=1e-10)
    assert sol_d.converged and sol_d.iterations < sol_l.iterations


def test_amortization_report_accounts_dirichlet_stage(prob2d):
    solver = FetiSolver(prob2d, FetiConfig(
        schur=CFG, preconditioner="dirichlet"))
    solver.preprocess()
    rep = solver.amortization_report(
        t_assembly_s=1.0, t_implicit_iter_s=0.15, t_explicit_iter_s=0.05,
        t_dirichlet_s=0.5)
    assert rep["amortization_iterations"] == pytest.approx(15.0)
    assert rep["dirichlet_s"] == 0.5
    d = rep["dirichlet_flops_per_subdomain"]
    assert d is not None and d["total"] > 0
    if solver.state.shared_factor:
        # the stage graph deduped the interior factorization entirely
        assert d["cholesky_ii"] == 0
        assert d["cholesky_ii_saved_by_sharing"] > 0
    else:
        assert d["total"] > d["cholesky_ii"] > 0


# --------------------------------------------------------------------------
# autotuner coverage: the dirichlet stage has its own plan + cache entry
# --------------------------------------------------------------------------


def test_autotuned_dirichlet_stage_plans_independently(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path))
    prob = decompose_problem("heat", 2, (2, 2), (4, 4))
    solver = FetiSolver(prob, FetiConfig(
        schur="auto", preconditioner="dirichlet", measure="model"))
    sol = solver.solve(tol=1e-9)
    assert sol.converged
    st = solver.state
    assert st.plan is not None and st.dirichlet_plan is not None
    assert st.plan.key != st.dirichlet_plan.key
    assert st.dirichlet_cfg == st.dirichlet_plan.cfg
    # both stages live in ONE joint graph cache entry (no per-stage files)
    assert st.graph_plan is not None
    cached = {p.name for p in tmp_path.iterdir() if p.name.endswith(".json")}
    assert cached == {f"graph-{st.graph_plan.key}.json"}
    # a second preprocess hits the joint entry for both stages
    solver2 = FetiSolver(prob, FetiConfig(
        schur="auto", preconditioner="dirichlet", measure="model"))
    solver2.preprocess()
    assert solver2.plan.from_cache
    assert solver2.state.dirichlet_plan.from_cache


# --------------------------------------------------------------------------
# sharded dirichlet (CI multidevice lane)
# --------------------------------------------------------------------------


@pytest.mark.multidevice
@pytest.mark.parametrize("storage", ["dense", "packed"])
def test_sharded_dirichlet_matches_single_device(prob2d, storage):
    from repro.launch.mesh import make_feti_mesh

    mesh = make_feti_mesh()
    fc = FetiConfig(schur=CFG, preconditioner="dirichlet",
                    storage=storage)
    sol_sh = FetiSolver(prob2d, fc.replace(mesh=mesh)).solve(tol=1e-10)
    sol1 = FetiSolver(prob2d, fc).solve(tol=1e-10)
    assert sol_sh.converged and sol1.converged
    # the shard_map-compiled S_b agrees with the single-device one only to
    # machine epsilon (different XLA schedule), so the stopping test may
    # flip by one iteration; the solutions must still coincide
    assert abs(sol_sh.iterations - sol1.iterations) <= 1
    assert np.max(np.abs(sol_sh.u_global - sol1.u_global)) < 1e-9
    assert _oracle_error(prob2d, sol_sh) <= 1e-8


@pytest.mark.multidevice
def test_sharded_dirichlet_state_padding(prob2d):
    """Padded dummy subdomains get identity S_b, zero Btb and zero
    own-boundary mask — they contribute exactly nothing to the psum."""
    from repro.feti import sharded as shlib
    from repro.launch.mesh import make_feti_mesh

    mesh = make_feti_mesh()
    st = preprocess_cluster(prob2d, FetiConfig(
        schur=CFG, mesh=mesh, preconditioner="dirichlet"))
    assert st.Sb.shape[0] % shlib.mesh_size(mesh) == 0
    Sb = np.asarray(st.Sb)
    Btb = np.asarray(st.Btb)
    for s in range(st.S_real, st.S):
        np.testing.assert_allclose(Sb[s], np.eye(Sb.shape[1]),
                                   rtol=0, atol=1e-12)
        assert np.all(Btb[s] == 0)
    nl = prob2d.n_lambda
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal(nl))
    out_sh = shlib.dirichlet_preconditioner(
        mesh, st.Sb, st.Btb, st.lambda_ids, nl, w)
    st1 = preprocess_cluster(prob2d, FetiConfig(
        schur=CFG, preconditioner="dirichlet"))
    out1 = dirichlet_preconditioner(st1.Sb, st1.Btb, st1.lambda_ids, nl, w)
    np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out1),
                               rtol=1e-12, atol=1e-12)
