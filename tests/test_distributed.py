"""Distribution substrate: sharding rules, checkpoint atomicity + elastic
restore, straggler monitor, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed import (
    ElasticPlan,
    StragglerMonitor,
    available_steps,
    batch_spec,
    bf16_compress,
    cache_shardings,
    latest_step,
    make_int8_error_feedback,
    param_shardings,
    restore_checkpoint,
    save_checkpoint,
)
from repro.distributed.sharding import _spec_for_param
from repro.models import init_cache, init_model


class FakeMesh:
    """Shape-only stand-in so sharding *rules* are testable on 1 device."""

    def __init__(self, shape: dict):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _arr(*shape):
    return jnp.zeros(shape, jnp.float32)


def test_spec_attention_weights():
    # (d, H*hd): FSDP on d, TP on heads
    assert _spec_for_param(MESH, "stack/body/0/inner/wq/w", _arr(4096, 4096)) \
        == P("data", "model")
    # stacked scan axis stays unsharded
    assert _spec_for_param(MESH, "stack/body/0/inner/wq/w",
                           _arr(10, 4096, 4096)) == P(None, "data", "model")
    # output projection: TP on input dim
    assert _spec_for_param(MESH, "stack/body/0/inner/wo/w", _arr(4096, 4096)) \
        == P("model", "data")


def test_spec_embed_and_head():
    assert _spec_for_param(MESH, "embed", _arr(49152, 4096)) == P("model", "data")
    assert _spec_for_param(MESH, "lm_head", _arr(4096, 49152)) == P("data", "model")


def test_spec_moe_experts_ep_when_divisible():
    # deepseek-like: 160 experts over model axis
    assert _spec_for_param(MESH, "stack/body/0/mlp/wi", _arr(160, 5120, 1536)) \
        == P("model", "data", None)
    # grok-like: 8 experts -> EP impossible, TP falls back to ff dim
    assert _spec_for_param(MESH, "stack/body/0/mlp/wi", _arr(8, 6144, 32768)) \
        == P(None, "data", "model")
    assert _spec_for_param(MESH, "stack/body/0/mlp/wo", _arr(8, 32768, 6144)) \
        == P(None, "model", "data")


def test_spec_indivisible_degrades_to_replication():
    # odd dims: nothing divides -> fully replicated, never an error
    assert _spec_for_param(MESH, "stack/body/0/inner/wq/w", _arr(37, 53)) \
        == P(None, None)


def test_spec_norms_replicated():
    assert _spec_for_param(MESH, "stack/body/0/norm1/scale", _arr(4096)) == P(None)


def test_batch_spec_multi_pod():
    assert batch_spec(MESH3) == P(("pod", "data"))
    assert batch_spec(MESH) == P(("data",))


def test_param_shardings_cover_real_model():
    """Every leaf of a real (smoke) param tree gets a sharding without
    raising; biggest leaves must not be fully replicated on the big mesh."""
    cfg = get_smoke_config("deepseek-v2-236b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = param_shardings(mesh, params)
    assert len(jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))) \
        == len(jax.tree.leaves(params))


def test_cache_shardings_seq_axis():
    cfg = get_smoke_config("granite-3-8b")
    cache = init_cache(cfg, batch=2, max_len=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = cache_shardings(mesh, cache)
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))
    assert leaves  # all leaves got specs


# ------------------------------------------------------------ checkpoint ----
def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)]}
    for s in (1, 2, 3, 4):
        save_checkpoint(d, s, tree, keep=2)
    assert available_steps(d) == [3, 4]
    assert latest_step(d) == 4
    got, step = restore_checkpoint(d, tree)
    assert step == 4
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 7, {"x": jnp.ones((3,))})
    assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore onto a different mesh layout (elastic restart)."""
    d = str(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(d, 1, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding

    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    got, _ = restore_checkpoint(d, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding.spec == P("data", "model")


# ------------------------------------------------------- fault tolerance ----
def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(num_hosts=4, window=4, threshold=1.5)
    for _ in range(4):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
    assert mon.stragglers() == [2]
    assert mon.healthy_hosts() == 3


def test_elastic_plan_power_of_two():
    plan = ElasticPlan(total_hosts=64, hosts_per_pod=8)
    out = plan.plan(surviving_hosts=49)  # 6 whole pods survive
    assert out["pods"] == 4  # largest pow2 <= 6
    assert out["global_batch_scale"] == pytest.approx(0.5)


# ---------------------------------------------------------- compression ----
def test_bf16_compress_close():
    g = {"w": jnp.linspace(-1, 1, 64, dtype=jnp.float32)}
    c = bf16_compress(g)
    np.testing.assert_allclose(np.asarray(c["w"]), np.asarray(g["w"]),
                               rtol=1e-2, atol=1e-2)
    assert c["w"].dtype == jnp.float32


def test_int8_error_feedback_converges_in_mean():
    """Accumulated compressed gradients converge to accumulated truth."""
    params = {"w": jnp.zeros((32,), jnp.float32)}
    transform, state = make_int8_error_feedback(params)
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(32), jnp.float32) * 1e-3
    acc_c = np.zeros(32)
    for _ in range(50):
        c, state = transform({"w": g_true}, state)
        acc_c += np.asarray(c["w"])
    np.testing.assert_allclose(acc_c, 50 * np.asarray(g_true),
                               rtol=0.05, atol=1e-4)
