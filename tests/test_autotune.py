"""Tests for the assembly autotuner + plan cache (repro.core.autotune).

Covers the ISSUE-1 acceptance set: plan-cache hit determinism, agreement of
``cfg="auto"`` with the best-scoring explicit config on a fixed pattern,
and numerical agreement of autotuned assembly with the dense baseline.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SchurAssemblyConfig,
    assembly_cost,
    build_stepped_meta,
    enumerate_space,
    make_assembler,
    plan,
    plan_assembly,
    schur_dense_baseline,
)
from repro.core.autotune import (
    assembly_bytes,
    clear_plan_cache,
    default_block_sizes,
    pattern_fingerprint,
    plan_cache_dir,
)
from repro.launch.roofline import DEVICE_MODELS, detect_device
from repro.testing import random_feti_like_bt


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    return tmp_path / "plans"


def test_plan_cache_dir_env_routing(tmp_path, monkeypatch):
    """$REPRO_PLAN_CACHE_DIR (the canonical, CI-facing spelling) wins over
    the legacy $REPRO_PLAN_CACHE, which wins over the home default —
    re-read at every access, not captured at import."""
    monkeypatch.delenv("REPRO_PLAN_CACHE_DIR", raising=False)
    monkeypatch.delenv("REPRO_PLAN_CACHE", raising=False)
    assert plan_cache_dir().endswith(os.path.join("repro", "plans"))
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "legacy"))
    assert plan_cache_dir() == str(tmp_path / "legacy")
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path / "canonical"))
    assert plan_cache_dir() == str(tmp_path / "canonical")


def _pattern(n=96, m=40, seed=0):
    rng = np.random.default_rng(seed)
    return random_feti_like_bt(n, m, rng) != 0


# ---------------------------------------------------------------- space ----

def test_enumerate_space_canonical():
    space = enumerate_space([16, 32])
    # no structural duplicates
    assert len(space) == len(set(space))
    # prune only toggles for factor_split, pallas never pairs dense/dense,
    # and packed storage only appears where it is native (factor_split)
    for cfg in space:
        if cfg.trsm_variant != "factor_split":
            assert not cfg.prune
        if cfg.use_pallas:
            assert not (cfg.trsm_variant == "dense"
                        and cfg.syrk_variant == "dense")
        if cfg.storage == "packed":
            assert cfg.trsm_variant == "factor_split"
        if cfg.fused:
            assert cfg.use_pallas
    # per block size: 12 dense non-pallas (9 combos + 3 extra prunes)
    # + 8 dense pallas + 3 packed factor_split + 3 packed pallas
    # + 2 fused megakernel (1 dense + 1 packed)
    assert len(space) == 2 * (12 + 8 + 3 + 3 + 2)
    assert sum(c.fused for c in space) == 4
    # every variant pair is represented
    pairs = {(c.trsm_variant, c.syrk_variant) for c in space}
    assert len(pairs) == 9
    # storage restriction prunes the space to one layout
    assert all(c.storage == "packed" for c in
               enumerate_space([16], storage="packed"))
    assert all(c.storage == "dense" for c in
               enumerate_space([16], storage="dense"))


def test_default_block_sizes_clip_to_problem():
    assert default_block_sizes(25) == (8, 16)
    assert max(default_block_sizes(5000)) == 256
    assert default_block_sizes(4) == (4,)


# ----------------------------------------------------------- cost model ----

def test_cost_model_positive_and_dense_single_op():
    pat = _pattern()
    meta = build_stepped_meta(pat, block_size=16)
    # storage is pinned: this asserts the DENSE baseline's launch count
    # (under the packed-default CI lane the env would flip it otherwise)
    dense = SchurAssemblyConfig("dense", "dense", 16, prune=False,
                                storage="dense")
    by = assembly_bytes(meta, dense)
    assert by["ops"] == 2  # one TRSM + one SYRK launch
    assert by["total"] > 0
    dev = DEVICE_MODELS["cpu"]
    for cfg in enumerate_space([16]):
        cost = assembly_cost(meta, cfg, dev)
        assert cost["total_s"] > 0
        assert cost["flops"] > 0


def test_pallas_never_wins_off_tpu():
    pat = _pattern()
    meta = build_stepped_meta(pat, block_size=16)
    dev = DEVICE_MODELS["cpu"]
    costs = {cfg: assembly_cost(meta, cfg, dev)["total_s"]
             for cfg in enumerate_space([16])}
    best = min(costs, key=costs.get)
    assert not best.use_pallas


# ----------------------------------------------------------- plan cache ----

def test_plan_cache_hit_determinism(tmp_cache):
    pat = _pattern()
    p1 = plan_assembly(pat, measure="never")
    assert not p1.from_cache
    p2 = plan_assembly(pat, measure="never")
    assert p2.from_cache
    assert p2.cfg == p1.cfg
    assert p2.key == p1.key
    assert p2.predicted_s == p1.predicted_s
    # same *pattern content* in a fresh array object also hits
    p3 = plan_assembly(pat.copy(), measure="never")
    assert p3.from_cache and p3.cfg == p1.cfg


def test_plan_cache_respects_pattern_and_device(tmp_cache):
    pat = _pattern(seed=1)
    p1 = plan_assembly(pat, measure="never")
    other = plan_assembly(_pattern(seed=2), measure="never")
    assert other.key != p1.key
    gpu = plan_assembly(pat, measure="never", device=DEVICE_MODELS["gpu"])
    assert gpu.key != p1.key
    assert not gpu.from_cache


def test_cache_can_be_disabled_and_cleared(tmp_cache):
    pat = _pattern(seed=3)
    plan_assembly(pat, measure="never")
    assert clear_plan_cache() >= 1
    p = plan_assembly(pat, measure="never", cache=False)
    assert not p.from_cache
    assert clear_plan_cache() == 0  # cache=False wrote nothing


def test_fingerprint_is_content_addressed():
    piv = np.array([0, 3, 5, 9])
    a = pattern_fingerprint(piv, 12, 4)
    assert a == pattern_fingerprint(piv.copy(), 12, 4)
    assert a != pattern_fingerprint(piv + 1, 12, 4)
    assert a != pattern_fingerprint(piv, 13, 4)


# ------------------------------------------------------ plan selection -----

def test_auto_equals_best_scoring_explicit_config(tmp_cache):
    """measure='never' planning must return exactly the roofline argmin."""
    pat = _pattern(n=128, m=48, seed=4)
    p = plan_assembly(pat, measure="never", block_sizes=(16, 32))
    dev = detect_device()
    best_cfg, best_s = None, float("inf")
    for cfg in enumerate_space((16, 32), interpret=dev.kind != "tpu"):
        meta = build_stepped_meta(pat, block_size=cfg.block_size,
                                  rhs_block_size=cfg.rhs_bs)
        s = assembly_cost(meta, cfg, dev)["total_s"]
        if s < best_s:
            best_cfg, best_s = cfg, s
    assert p.cfg == best_cfg
    assert p.predicted_s == pytest.approx(best_s)


def test_plan_summary_mentions_choice(tmp_cache):
    p = plan_assembly(_pattern(seed=5), measure="never")
    s = p.summary()
    assert p.cfg.trsm_variant in s and p.cfg.syrk_variant in s
    assert "predicted" in s


# ------------------------------------------------- numerical agreement -----

def test_autotuned_assembly_matches_dense_baseline(tmp_cache):
    rng = np.random.default_rng(6)
    n, m = 96, 40
    Bt = random_feti_like_bt(n, m, rng)
    p = plan_assembly(Bt != 0, measure="never")
    meta = build_stepped_meta(Bt != 0, block_size=p.cfg.block_size,
                              rhs_block_size=p.cfg.rhs_bs)
    L = np.tril(rng.standard_normal((n, n))) * 0.1
    np.fill_diagonal(L, 1.0 + rng.random(n))
    Lj, Btj = jnp.asarray(L), jnp.asarray(Bt)
    F_auto = make_assembler(meta, p.cfg)(Lj, Btj)
    F_ref = schur_dense_baseline(Lj, Btj)
    assert float(jnp.max(jnp.abs(F_auto - F_ref))) < 1e-8


def test_preprocess_cluster_auto_end_to_end(tmp_cache):
    """cfg='auto' flows through the cluster path; SCs match the baseline."""
    from repro.fem import decompose_heat_problem
    from repro.feti import FetiConfig, preprocess_cluster

    prob = decompose_heat_problem(2, (2, 2), (4, 4))
    st = preprocess_cluster(prob, FetiConfig(schur="auto",
                                             measure="never"))
    assert isinstance(st.cfg, SchurAssemblyConfig)
    assert st.plan is not None
    assert st.plan.cfg == st.cfg
    F_ref = jax.vmap(schur_dense_baseline)(st.L, st.Btp)
    assert float(jnp.max(jnp.abs(st.F - F_ref))) < 1e-8
    # second preprocess is a cache hit with the same plan
    st2 = preprocess_cluster(prob, FetiConfig(schur="auto",
                                              measure="never"))
    assert st2.plan.from_cache
    assert st2.cfg == st.cfg


def test_solver_accepts_auto(tmp_cache):
    from repro.fem import decompose_heat_problem
    from repro.feti import FetiConfig, FetiSolver

    prob = decompose_heat_problem(2, (2, 2), (4, 4))
    solver = FetiSolver(prob, FetiConfig(schur="auto", measure="never"))
    sol = solver.solve(tol=1e-9)
    assert sol.converged
    assert isinstance(solver.cfg, SchurAssemblyConfig)
    assert solver.plan is not None
    # agrees with the hand-picked default config's solution
    ref = FetiSolver(prob, SchurAssemblyConfig(block_size=8)).solve(tol=1e-9)
    assert np.allclose(sol.u_global, ref.u_global, atol=1e-8)


def test_plan_facade_exported():
    assert plan is plan_assembly


def test_plan_json_roundtrip(tmp_cache):
    from repro.core.autotune import Plan

    p = plan_assembly(_pattern(seed=7), measure="never")
    q = Plan.from_json(p.to_json())
    assert q.cfg == p.cfg and q.from_cache
    assert dataclasses.asdict(q.cfg) == dataclasses.asdict(p.cfg)
