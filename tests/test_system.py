"""End-to-end behaviour tests for the whole system: the paper's pipeline
from FEM assembly through sparsity-utilizing SC assembly to a validated
FETI solve, plus the LM framework loop (train -> checkpoint -> resume ->
serve) — the two spines every other test hangs off."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import SchurAssemblyConfig
from repro.data import synthetic_batch
from repro.distributed import restore_checkpoint, save_checkpoint
from repro.fem import decompose_heat_problem
from repro.feti import FetiConfig, FetiSolver
from repro.models import init_model
from repro.train import (
    OptimizerConfig,
    TrainConfig,
    adamw_init,
    make_train_step,
)
from repro.train.serve_step import greedy_generate


def test_paper_pipeline_end_to_end():
    """Mesh -> decompose -> factorize -> stepped SC assembly -> PCPG ->
    solution matches the undecomposed solve; explicit == implicit."""
    prob = decompose_heat_problem(2, (2, 2), (6, 6))
    cfg = SchurAssemblyConfig(trsm_variant="factor_split",
                              syrk_variant="input_split",
                              block_size=8, rhs_block_size=8)
    u_ref = prob.reference_solution()
    results = {}
    for mode in ("explicit", "implicit"):
        sol = FetiSolver(prob, FetiConfig(
            schur=cfg, mode=mode)).solve(tol=1e-10)
        assert sol.converged
        np.testing.assert_allclose(sol.u_global, u_ref,
                                   atol=1e-8 * np.abs(u_ref).max())
        results[mode] = sol
    # both operators drive PCPG through the same Krylov space
    assert results["explicit"].iterations == results["implicit"].iterations


def test_lm_framework_loop(tmp_path):
    """Train a smoke model, checkpoint, resume, keep training, serve."""
    cfg = get_smoke_config("granite-3-8b")
    tcfg = TrainConfig(optimizer=OptimizerConfig(learning_rate=1e-3,
                                                 warmup_steps=2,
                                                 total_steps=20),
                       remat=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, tcfg.optimizer)
    step = jax.jit(make_train_step(cfg, tcfg))

    for i in range(4):
        params, opt, metrics = step(params, opt,
                                    synthetic_batch(cfg, 4, 16, seed=3, step=i))
    save_checkpoint(str(tmp_path), 4, {"params": params, "opt": opt})

    # resume into freshly-initialized templates
    template = {"params": init_model(jax.random.PRNGKey(1), cfg),
                "opt": adamw_init(params, tcfg.optimizer)}
    state, step_no = restore_checkpoint(str(tmp_path), template)
    assert step_no == 4
    r_params, r_opt = state["params"], state["opt"]
    for a, b in zip(jax.tree.leaves(r_params), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))

    # resumed state keeps training (bitwise same path as uninterrupted)
    p1, o1, m1 = step(params, opt, synthetic_batch(cfg, 4, 16, seed=3, step=4))
    p2, o2, m2 = step(r_params, r_opt,
                      synthetic_batch(cfg, 4, 16, seed=3, step=4))
    assert float(m1["loss"]) == float(m2["loss"])

    # and serves
    gen, _ = greedy_generate(p2, cfg, jnp.asarray([[1, 2, 3]], jnp.int32),
                             steps=4)
    assert gen.shape == (1, 4)
