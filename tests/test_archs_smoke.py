"""Per-architecture smoke tests: every assigned arch instantiates a reduced
config of the same family and runs one forward + one train step on CPU,
asserting output shapes and no NaNs. The FETI archs run one reduced solve.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FetiArchConfig, get_config, get_smoke_config, list_archs
from repro.data import synthetic_batch
from repro.models import forward, init_model
from repro.train import OptimizerConfig, TrainConfig, adamw_init, make_train_step

LM_ARCHS = [a for a in list_archs() if not a.startswith("feti")]
FETI_ARCHS = [a for a in list_archs() if a.startswith("feti")]

# exact assigned numbers, re-stated so a config edit can't silently drift
EXPECTED_FULL = {
    "qwen2-vl-2b": dict(num_layers=28, d_model=1536, num_heads=12,
                        num_kv_heads=2, d_ff=8960, vocab_size=151936),
    "granite-3-8b": dict(num_layers=40, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12800, vocab_size=49155),
    "nemotron-4-340b": dict(num_layers=96, d_model=18432, num_heads=96,
                            num_kv_heads=8, d_ff=73728, vocab_size=256000,
                            mlp_kind="squared_relu"),
    "qwen1.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                        num_kv_heads=40, d_ff=27392, vocab_size=152064,
                        qkv_bias=True),
    "mistral-large-123b": dict(num_layers=88, d_model=12288, num_heads=96,
                               num_kv_heads=8, d_ff=28672, vocab_size=32768),
    "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                              num_kv_heads=1, d_ff=7680, vocab_size=256000),
    "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                       vocab_size=65536, attn_kind="none"),
    "grok-1-314b": dict(num_layers=64, d_model=6144, num_heads=48,
                        num_kv_heads=8, d_ff=32768, vocab_size=131072,
                        num_experts=8, top_k=2),
    "deepseek-v2-236b": dict(num_layers=60, d_model=5120, num_heads=128,
                             vocab_size=102400, attn_kind="mla",
                             kv_lora_rank=512, num_experts=160, top_k=6,
                             num_shared_experts=2, moe_d_ff=1536),
    "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                          num_kv_heads=16, d_ff=5120, vocab_size=504,
                          causal=False),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED_FULL))
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    for field, want in EXPECTED_FULL[arch].items():
        assert getattr(cfg, field) == want, (arch, field)


def test_param_counts_in_expected_range():
    """Analytic parameter counts must land near the advertised sizes."""
    expected_b = {
        "qwen2-vl-2b": (1.2, 2.6),
        "granite-3-8b": (6.5, 9.5),
        "nemotron-4-340b": (300, 380),
        "qwen1.5-32b": (28, 36),
        "mistral-large-123b": (110, 135),
        "recurrentgemma-2b": (2.0, 3.3),
        "rwkv6-1.6b": (1.3, 2.2),
        "grok-1-314b": (280, 345),
        "deepseek-v2-236b": (200, 260),
        "hubert-xlarge": (0.7, 1.3),
    }
    for arch, (lo, hi) in expected_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = synthetic_batch(cfg, B, S, seed=0)

    logits, _, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN/inf logits"

    tcfg = TrainConfig(optimizer=OptimizerConfig(learning_rate=1e-3,
                                                 warmup_steps=1,
                                                 total_steps=10),
                       remat=False)
    step = make_train_step(cfg, tcfg)
    opt = adamw_init(params, tcfg.optimizer)
    params2, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", [a for a in LM_ARCHS
                                  if a not in ("hubert-xlarge",)])
def test_smoke_decode_step(arch):
    """One decode step with a cache (encoder-only archs have none)."""
    from repro.models import init_cache

    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = init_cache(cfg, B, 8)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.family == "vlm":
        batch["positions"] = jnp.zeros((B, 1, 3), jnp.int32)
    logits, cache, _ = forward(params, cfg, batch, cache=cache,
                               cache_index=jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", FETI_ARCHS)
def test_smoke_feti_solve(arch):
    from repro.core import SchurAssemblyConfig
    from repro.fem import decompose_problem
    from repro.feti import FetiSolver

    fc = get_smoke_config(arch)
    assert isinstance(fc, FetiArchConfig)
    prob = decompose_problem(fc.problem, fc.dim, fc.sub_grid,
                             fc.elems_per_sub)
    cfg = SchurAssemblyConfig(
        trsm_variant=fc.trsm_variant, syrk_variant=fc.syrk_variant,
        block_size=fc.block_size, rhs_block_size=fc.rhs_block_size,
    )
    sol = FetiSolver(prob, cfg).solve(tol=1e-9)
    assert sol.converged
    u_ref = prob.reference_solution()
    np.testing.assert_allclose(sol.u_global, u_ref,
                               atol=1e-6 * max(abs(u_ref).max(), 1))


def test_long_context_applicability_flags():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §5)."""
    subq = {a for a in LM_ARCHS if get_config(a).is_subquadratic}
    assert subq == {"rwkv6-1.6b", "recurrentgemma-2b"}
