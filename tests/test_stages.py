"""Stage-graph tests (ISSUE 7): the fused TRSM→SYRK megakernel against the
two-kernel schedule, the shared-interior-factor dedup against the
two-pipeline baseline, joint plan-cache behavior, and the 3D-elasticity
regression for the single-computation dof_perm threading."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SchurAssemblyConfig, StageGraph, StageSpec
from repro.core.stages import _store_graph  # noqa: F401  (cache dir reuse)
from repro.fem.decomposition import (
    decompose_elasticity_problem,
    decompose_heat_problem,
)
from repro.fem.regularization import fixing_dofs_regularization
from repro.feti import FetiConfig, preprocess_cluster
from repro.feti import dirichlet as dirlib
from repro.feti.assembly import batched_assemble
from repro.sparse.cholesky import block_cholesky

pytestmark = pytest.mark.stages


# ------------------------------------------------- fused megakernel ----

@pytest.mark.parametrize("ordering", ["nd", "rcm"])
@pytest.mark.parametrize("storage", ["dense", "packed"])
@pytest.mark.parametrize("bs", [8, 16])
def test_fused_matches_unfused(ordering, storage, bs):
    """The fused megakernel (one Pallas program keeping the TRSM panels in
    VMEM) agrees with the separately-scheduled TRSM + SYRK pipeline to
    1e-12 across storage layouts, orderings and block sizes (interpret
    mode exercises the exact kernel logic on CPU)."""
    prob = decompose_heat_problem(2, (2, 2), (4, 4))
    base = SchurAssemblyConfig(block_size=bs, storage=storage)
    fused = SchurAssemblyConfig(block_size=bs, storage=storage,
                                use_pallas=True, fused=True, interpret=True)
    st0 = preprocess_cluster(prob, FetiConfig(schur=base, ordering=ordering))
    st1 = preprocess_cluster(prob, FetiConfig(schur=fused, ordering=ordering))
    assert st1.cfg.fused
    err = np.max(np.abs(np.asarray(st0.F) - np.asarray(st1.F)))
    assert err <= 1e-12, err


def test_fused_requires_pallas():
    with pytest.raises(ValueError, match="fused"):
        SchurAssemblyConfig(fused=True, use_pallas=False)


def test_fused_smoke_solve():
    """Tier-1 smoke: a full PCPG solve through the fused megakernel."""
    from repro.feti import FetiSolver

    prob = decompose_heat_problem(2, (2, 2), (3, 3))
    cfg = SchurAssemblyConfig(block_size=8, use_pallas=True, fused=True,
                              interpret=True)
    sol = FetiSolver(prob, FetiConfig(schur=cfg)).solve()
    assert sol.converged
    ref = prob.reference_solution()
    err = np.max(np.abs(sol.u_global - ref)) / np.abs(ref).max()
    assert err < 1e-8, err


# --------------------------------------- shared-interior-factor dedup ----

def _elasticity_problem():
    # corner fixing nodes lie on the union boundary -> sharing is valid
    return decompose_elasticity_problem(2, (2, 2), (3, 3))


def test_shared_factor_bit_identical_to_two_pipelines():
    """With the dual rows in split.dperm order and a block size dividing
    n_i, the stage graph's shared path produces BIT-identical F and S_b to
    independently-run dual + Dirichlet pipelines in the same ordering:
    sharing changes where the interior factor comes from, not one bit of
    what is computed."""
    prob = _elasticity_problem()
    cfg = SchurAssemblyConfig(block_size=4)  # divides n_i = 8
    st = preprocess_cluster(
        prob, FetiConfig(schur=cfg, preconditioner="dirichlet"))
    assert st.shared_factor
    split = st.split
    assert split.n_i % cfg.block_size == 0
    dperm = split.dperm
    assert np.array_equal(st.node_perm, dperm)

    # pipeline 1 (dual): factorize regularized K in the same dperm order,
    # assemble F with the same metadata — the pre-graph computation
    Kreg = np.stack([fixing_dofs_regularization(sd.K, sd.fixing_dofs)
                     for sd in prob.subdomains])
    Kp = jnp.asarray(Kreg[:, dperm][:, :, dperm])
    L_ref = jax.vmap(
        lambda A: block_cholesky(A, cfg.block_size, mask=st.block_mask))(Kp)
    Btp = jnp.asarray(np.stack([sd.Bt[dperm] for sd in prob.subdomains],
                               dtype=np.float64))
    F_ref = batched_assemble(L_ref, Btp, st.col_perm, st.inv_col_perm,
                             st.env, cfg, st.block_mask)

    # pipeline 2 (dirichlet): its OWN interior factorization of the
    # unregularized K_ii (shared=False assembler), same symbolic products
    d_assemble = dirlib.make_dirichlet_assembler(
        split, st.dirichlet_env, st.dirichlet_mask, st.dirichlet_cfg)
    Kd = jnp.asarray(np.stack(
        [sd.K[dperm][:, dperm] for sd in prob.subdomains]))
    Zb = jnp.asarray(dirlib.own_boundary_masks(prob, split))
    Sb_ref = jax.vmap(dirlib.restrict_own_boundary)(
        jax.vmap(d_assemble)(Kd), Zb)

    assert np.array_equal(np.asarray(st.F), np.asarray(F_ref))
    assert np.array_equal(np.asarray(st.Sb), np.asarray(Sb_ref))


@pytest.mark.parametrize("storage", ["dense", "packed"])
def test_shared_vs_unshared_agree(storage):
    """share_factor=False keeps the two independent pipelines (the dual in
    plain fill-reducing order); outputs agree with the shared path to
    1e-12 — the orderings differ, so only numerically."""
    prob = _elasticity_problem()
    fc = FetiConfig(preconditioner="dirichlet", storage=storage)
    st1 = preprocess_cluster(prob, fc)
    st0 = preprocess_cluster(prob, fc.replace(share_factor=False))
    assert st1.shared_factor and not st0.shared_factor
    assert np.max(np.abs(np.asarray(st1.Sb) - np.asarray(st0.Sb))) <= 1e-12
    assert np.max(np.abs(np.asarray(st1.F) - np.asarray(st0.F))) <= 1e-12


def test_share_factor_auto_disables_on_interior_fixing_dofs():
    """The heat workload fixes the subdomain CENTER node — interior — so
    the regularization would perturb the shared factor: 'auto' must fall
    back to the two-pipeline form, and share_factor=True must refuse."""
    prob = decompose_heat_problem(2, (2, 2), (3, 3))
    st = preprocess_cluster(prob, FetiConfig(preconditioner="dirichlet"))
    assert not st.shared_factor
    with pytest.raises(ValueError, match="share_factor"):
        preprocess_cluster(
            prob, FetiConfig(preconditioner="dirichlet", share_factor=True))


def test_state_stage_views():
    """ClusterState exposes the graph view: outputs keyed by stage name,
    per-stage device-byte attribution, resolved stages."""
    prob = _elasticity_problem()
    st = preprocess_cluster(prob, FetiConfig(preconditioner="dirichlet"))
    out = st.outputs()
    assert set(out) == {"dual", "dirichlet"}
    assert out["dual"] is st.F and out["dirichlet"] is st.Sb
    assert set(st.stages) == {"dual", "dirichlet"}
    assert st.stages["dirichlet"].spec.share_factor_of == "dual"
    by = st.device_bytes()["per_stage"]
    assert set(by) == {"dual", "dirichlet"}
    assert by["dual"] > 0 and by["dirichlet"] > 0


# ------------------------------------------------- joint plan cache ----

def test_joint_plan_cache_hit_miss(tmp_path, monkeypatch):
    """One graph cache entry covers ALL stages: second identical build
    hits; changing any stage's sparsity fingerprint misses."""
    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path))
    prob = _elasticity_problem()
    fc = FetiConfig(schur="auto", preconditioner="dirichlet",
                    measure="never")
    st1 = preprocess_cluster(prob, fc)
    assert st1.graph_plan is not None and not st1.graph_plan.from_cache
    assert set(st1.graph_plan.plans) == {"dual", "dirichlet"}
    st2 = preprocess_cluster(prob, fc)
    assert st2.graph_plan.from_cache
    assert st2.graph_plan.key == st1.graph_plan.key
    assert np.array_equal(np.asarray(st1.F), np.asarray(st2.F))
    assert np.array_equal(np.asarray(st1.Sb), np.asarray(st2.Sb))
    # a different decomposition (different sparsity) -> different key
    st3 = preprocess_cluster(decompose_elasticity_problem(2, (2, 2), (4, 4)),
                             fc)
    assert st3.graph_plan.key != st1.graph_plan.key
    assert not st3.graph_plan.from_cache


def test_stage_graph_validates_wiring():
    def builder(bs, rbs):  # pragma: no cover - never called
        raise AssertionError

    a = StageSpec(name="a", builder=builder, fingerprint="fa", n=8)
    with pytest.raises(ValueError, match="duplicate"):
        StageGraph([a, StageSpec(name="a", builder=builder,
                                 fingerprint="fb", n=8)])
    with pytest.raises(ValueError, match="earlier stage"):
        StageGraph([StageSpec(name="b", builder=builder, fingerprint="fb",
                              n=8, share_factor_of="zzz")])


# --------------------------------- dof_perm threading (3D regression) ----

def test_split_threading_3d_elasticity():
    """The preprocessor computes the fill-reducing DOF permutation ONCE
    and threads it into boundary_interior_split (which used to silently
    rebuild it — a drift hazard this 3D vector-DOF case would catch):
    the threaded split must equal the standalone rebuild, and the full
    shared-factor Dirichlet pipeline must match the one-shot oracle."""
    prob = decompose_elasticity_problem(3, (2, 1, 1), (2, 2, 2))
    st = preprocess_cluster(prob, FetiConfig(preconditioner="dirichlet"))
    ref = dirlib.boundary_interior_split(prob, ordering="nd")
    assert np.array_equal(st.split.interior, ref.interior)
    assert np.array_equal(st.split.boundary, ref.boundary)
    assert st.shared_factor  # 3D corner fixing nodes are boundary
    Sb_ref, _, _ = dirlib.assemble_dirichlet_schur(prob)
    err = np.max(np.abs(np.asarray(st.Sb) - np.asarray(Sb_ref)))
    assert err <= 1e-12, err
