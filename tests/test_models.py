"""Model-stack correctness: flash attention vs naive softmax, chunked RWKV6
vs naive recurrence, RG-LRU associative scan vs sequential, MoE dispatch,
and prefill+decode consistency across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, forward, init_cache, init_model
from repro.models.attention import flash_attention
from repro.models.layers import apply_mrope, apply_rope
from repro.models.moe import init_moe, moe_block
from repro.models.rglru import _lru_scan
from repro.models.rwkv6 import _wkv_chunked


# ------------------------------------------------------------ attention ----
def _naive_attention(q, k, v, causal, window=0, scale=None):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = scale or D ** -0.5
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * scale, kk)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("causal,window,skip", [
    (True, 0, False),
    (True, 0, True),
    (False, 0, False),
    (True, 8, False),
])
def test_flash_attention_matches_naive(causal, window, skip):
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    got = flash_attention(q, k, v, pos, pos, causal=causal, window=window,
                          q_chunk=16, kv_chunk=16, skip_masked_blocks=skip)
    want = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_ragged_chunks():
    """Sizes that don't divide the chunk hint must still be exact."""
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 48, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    got = flash_attention(q, k, v, pos, pos, causal=True, q_chunk=13, kv_chunk=7)
    want = _naive_attention(q, k, v, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- rope ----
def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 4, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 4, 1, 16)), jnp.float32)
    p0 = jnp.arange(4, dtype=jnp.int32)[None]
    p1 = p0 + 100
    s0 = jnp.einsum("bqhd,bkhd->bqk", apply_rope(q, p0, 1e4), apply_rope(k, p0, 1e4))
    s1 = jnp.einsum("bqhd,bkhd->bqk", apply_rope(q, p1, 1e4), apply_rope(k, p1, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-4, atol=1e-4)


def test_mrope_equals_rope_for_text():
    """With equal (t,h,w) positions M-RoPE must reduce to plain RoPE."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 8, 2, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 8, 3))
    got = apply_mrope(x, pos3, 1e4, (4, 6, 6))
    want = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- rwkv6 ----
def _wkv_naive(r, k, v, w, u, S0):
    B, S, H, D = r.shape
    out = np.zeros((B, S, H, D), np.float64)
    St = np.asarray(S0, np.float64).copy()
    r_, k_, v_, w_ = (np.asarray(t, np.float64) for t in (r, k, v, w))
    u_ = np.asarray(u, np.float64)
    for t in range(S):
        kv = np.einsum("bhd,bhe->bhde", k_[:, t], v_[:, t])
        out[:, t] = np.einsum("bhd,bhde->bhe", r_[:, t], St + u_[None, :, :, None] * kv)
        St = w_[:, t][..., None] * St + kv
    return out, St


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_rwkv6_chunked_matches_naive(chunk):
    rng = np.random.default_rng(4)
    B, S, H, D = 2, 32, 2, 8
    r = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32) * 0.5
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32) * 0.5
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32) * 0.5
    w = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, H, D)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, D)), jnp.float32) * 0.1
    S0 = jnp.asarray(rng.standard_normal((B, H, D, D)), jnp.float32) * 0.1
    got, S_fin = _wkv_chunked(r, k, v, w, u, chunk, S0)
    want, S_want = _wkv_naive(r, k, v, w, u, S0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_fin), S_want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- rg-lru ----
def test_lru_scan_matches_sequential():
    rng = np.random.default_rng(5)
    B, S, W = 2, 37, 8
    a = jnp.asarray(rng.uniform(0.5, 0.999, (B, S, W)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, W)), jnp.float32)
    got = np.asarray(_lru_scan(a, u, h0))
    h = np.asarray(h0, np.float64)
    a_, u_ = np.asarray(a, np.float64), np.asarray(u, np.float64)
    for t in range(S):
        h = a_[:, t] * h + u_[:, t]
        np.testing.assert_allclose(got[:, t], h, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- moe ----
def test_moe_single_expert_equals_dense_swiglu():
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      d_ff=32, vocab_size=7, num_heads=2, num_kv_heads=2,
                      num_experts=1, top_k=1, moe_d_ff=32,
                      capacity_factor=4.0, dtype="float32",
                      param_dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    y, aux = moe_block(p, cfg, x)
    want = jnp.einsum(
        "bsf,fd->bsd",
        jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"][0]))
        * jnp.einsum("bsd,df->bsf", x, p["wi"][0]),
        p["wo"][0],
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_routes_and_balances():
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      d_ff=32, vocab_size=7, num_heads=2, num_kv_heads=2,
                      num_experts=4, top_k=2, moe_d_ff=16,
                      capacity_factor=2.0, dtype="float32",
                      param_dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16), jnp.float32)
    y, aux = moe_block(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


# ----------------------------------------------- prefill/decode parity ----
FAMS = {
    "gqa": dict(family="dense", num_layers=2, d_model=32, d_ff=64,
                vocab_size=31, num_heads=4, num_kv_heads=2),
    "mla": dict(family="moe", num_layers=2, d_model=32, d_ff=64, vocab_size=31,
                num_heads=2, attn_kind="mla", q_lora_rank=16, kv_lora_rank=8,
                qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8),
    "rwkv6": dict(family="ssm", num_layers=2, d_model=32, d_ff=64,
                  vocab_size=31, layer_pattern=("rwkv6",), attn_kind="none",
                  rwkv_head_dim=8),
    "hybrid": dict(family="hybrid", num_layers=3, d_model=32, d_ff=64,
                   vocab_size=31, num_heads=2, num_kv_heads=1,
                   layer_pattern=("rglru", "rglru", "attn"), local_window=8,
                   lru_width=32),
}


@pytest.mark.parametrize("fam", sorted(FAMS))
def test_prefill_then_decode_matches_full_forward(fam):
    cfg = ModelConfig(name=fam, dtype="float32", param_dtype="float32",
                      **FAMS[fam])
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    full_logits, _, _ = forward(params, cfg, {"tokens": toks})

    cache = init_cache(cfg, B, 16)
    pre_logits, cache, _ = forward(
        params, cfg, {"tokens": toks[:, : S - 1]}, cache=cache,
        cache_index=jnp.asarray(0, jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(pre_logits, np.float64),
        np.asarray(full_logits[:, : S - 1], np.float64),
        rtol=2e-3, atol=2e-3,
    )
    dec_logits, cache, _ = forward(
        params, cfg, {"tokens": toks[:, S - 1 :]}, cache=cache,
        cache_index=jnp.asarray(S - 1, jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float64),
        np.asarray(full_logits[:, S - 1], np.float64),
        rtol=2e-3, atol=2e-3,
    )


def test_ring_buffer_local_attention_decode():
    """Hybrid decode beyond the window: ring cache must match a full-cache
    run restricted to the window."""
    cfg = ModelConfig(name="h", dtype="float32", param_dtype="float32",
                      **FAMS["hybrid"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 24  # window is 8, cache ring is 8 slots
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, cfg, {"tokens": toks})

    cache = init_cache(cfg, B, 8)
    logits = None
    for t in range(S):
        logits, cache, _ = forward(
            params, cfg, {"tokens": toks[:, t : t + 1]}, cache=cache,
            cache_index=jnp.asarray(t, jnp.int32),
        )
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float64),
        np.asarray(full_logits[:, -1], np.float64),
        rtol=5e-3, atol=5e-3,
    )


def test_moe_sort_dispatch_matches_gshard():
    """With capacity ample (no drops) the sort/gather dispatch must equal
    the GShard one-hot-einsum dispatch exactly."""
    import dataclasses

    base = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                       d_ff=32, vocab_size=7, num_heads=2, num_kv_heads=2,
                       num_experts=4, top_k=2, moe_d_ff=16,
                       num_shared_experts=1, capacity_factor=8.0,
                       dtype="float32", param_dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16), jnp.float32)
    y_gshard, aux_g = moe_block(p, base, x)
    y_sort, aux_s = moe_block(
        p, dataclasses.replace(base, moe_impl="sort"), x)
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_gshard),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_g), rtol=1e-6)


def test_moe_sort_dispatch_capacity_drops_bounded():
    """With tight capacity the sort path must stay finite and bounded."""
    import dataclasses

    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      d_ff=32, vocab_size=7, num_heads=2, num_kv_heads=2,
                      num_experts=4, top_k=2, moe_d_ff=16,
                      capacity_factor=0.5, moe_impl="sort",
                      dtype="float32", param_dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16), jnp.float32)
    y, aux = moe_block(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(y)).max() < 1e3
