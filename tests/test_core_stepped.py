"""Unit + property tests for the stepped-shape analysis (paper §3)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import stepped as sp
from repro.testing import random_feti_like_bt


def test_column_pivots_basic():
    pat = np.array(
        [
            [0, 1, 0, 0],
            [1, 0, 0, 0],
            [0, 1, 1, 0],
        ]
    )
    piv = sp.column_pivots(pat)
    assert piv.tolist() == [1, 0, 2, 3]  # empty column -> n


def test_row_trails_basic():
    pat = np.array(
        [
            [0, 1, 0, 0],
            [0, 0, 0, 0],
            [1, 1, 1, 0],
        ]
    )
    assert sp.row_trails(pat).tolist() == [1, -1, 2]


def test_stepped_permutation_sorts_pivots():
    rng = np.random.default_rng(0)
    pat = rng.random((40, 17)) < 0.1
    piv = sp.column_pivots(pat)
    perm = sp.stepped_permutation(piv)
    assert np.all(np.diff(piv[perm]) >= 0)


def test_meta_widths_monotone_and_consistent():
    rng = np.random.default_rng(1)
    Bt = random_feti_like_bt(100, 37, rng)
    meta = sp.build_stepped_meta(Bt != 0, block_size=16, rhs_block_size=8)
    assert np.all(np.diff(meta.widths) >= 0)
    assert meta.widths[-1] <= meta.m
    # width at the last row counts every non-empty column
    nonempty = int((meta.pivots < meta.n).sum())
    assert meta.width_at_row(meta.n - 1) == nonempty
    # col_starts non-decreasing because pivots are sorted
    assert np.all(np.diff(meta.col_starts) >= 0)


def test_meta_blocks_cover_exactly():
    rng = np.random.default_rng(2)
    Bt = random_feti_like_bt(53, 21, rng)  # deliberately non-multiple sizes
    meta = sp.build_stepped_meta(Bt != 0, block_size=16, rhs_block_size=8)
    rows = [meta.row_block(k) for k in range(meta.num_row_blocks)]
    assert rows[0][0] == 0 and rows[-1][1] == meta.n
    assert all(a[1] == b[0] for a, b in zip(rows, rows[1:]))
    cols = [meta.col_block(c) for c in range(meta.num_col_blocks)]
    assert cols[0][0] == 0 and cols[-1][1] == meta.m


def test_flop_model_splitting_never_exceeds_dense():
    rng = np.random.default_rng(3)
    Bt = random_feti_like_bt(128, 64, rng)
    meta = sp.build_stepped_meta(Bt != 0, block_size=16)
    assert meta.flops_trsm_rhs_split() <= meta.flops_trsm_dense()
    assert meta.flops_syrk_input_split() <= meta.flops_syrk_dense()
    assert meta.flops_syrk_output_split() <= meta.flops_syrk_dense()


def test_theoretical_speedup_perfect_triangle():
    """Paper §4.3: for a perfectly triangular RHS the dense-variant speedup
    of both TRSM and SYRK tends to 3 (prism/pyramid volume ratio)."""
    n = m = 3000
    pat = np.tril(np.ones((n, m), dtype=bool))  # pivot of col j at row j
    meta = sp.build_stepped_meta(pat, block_size=10, presorted=True)
    tr_speedup = meta.flops_trsm_dense() / meta.flops_trsm_rhs_split()
    sy_speedup = meta.flops_syrk_dense() / meta.flops_syrk_input_split()
    assert tr_speedup == pytest.approx(3.0, rel=0.05)
    assert sy_speedup == pytest.approx(3.0, rel=0.05)


def test_shared_envelope_is_conservative():
    rng = np.random.default_rng(4)
    metas = []
    pats = []
    for _ in range(4):
        Bt = random_feti_like_bt(64, 32, rng)
        pats.append(Bt != 0)
        metas.append(sp.build_stepped_meta(Bt != 0, block_size=16))
    env = sp.shared_envelope(metas)
    for me in metas:
        assert np.all(env.widths >= me.widths)
        assert np.all(env.col_starts <= me.col_starts)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(8, 96),
    m=st.integers(1, 48),
    density=st.floats(0.01, 0.4),
    bs=st.integers(4, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_meta_invariants(n, m, density, bs, seed):
    rng = np.random.default_rng(seed)
    pat = rng.random((n, m)) < density
    meta = sp.build_stepped_meta(pat, block_size=bs)
    # permuted pivots sorted
    assert np.all(np.diff(meta.pivots) >= 0)
    # perm/inv_perm are inverse bijections
    assert np.array_equal(meta.perm[meta.inv_perm], np.arange(m))
    assert np.array_equal(meta.inv_perm[meta.perm], np.arange(m))
    # widths consistent with pivots
    for k in range(meta.num_row_blocks):
        _, end = meta.row_block(k)
        assert meta.widths[k] == int((meta.pivots < end).sum())
    # the permuted pattern really is stepped: zeros above pivots
    pp = pat[:, meta.perm]
    for j in range(m):
        if meta.pivots[j] < n:
            assert not pp[: meta.pivots[j], j].any()
