"""Test-wide config.

Two jobs:

1. Guard optional dependencies so *collection never hard-errors*:
   ``jax``/``numpy`` are hard requirements of every module under test, so
   they are ``pytest.importorskip``'d here (one clean skip instead of 13
   collection tracebacks).  ``hypothesis`` is optional — when it is missing
   a deterministic fallback (tests/_hypothesis_fallback.py) is installed in
   ``sys.modules`` so the property-test modules still run as seeded random
   sweeps.

2. x64 is enabled for the numerical-linear-algebra substrate (FEM /
   Cholesky / FETI convergence checks need it). Model code passes explicit
   dtypes so the LM smoke tests are unaffected. Device count stays at 1 —
   only the dry-run launcher (a separate process) requests 512 placeholder
   devices.

3. The ``multidevice`` marker (registered in pyproject.toml) tags tests
   that need a real multi-device backend (distributed FETI). They
   auto-skip when fewer than 2 devices exist, so the tier-1 suite stays
   green on single-device runs; the CI ``multidevice`` lane forces 8 host
   devices via XLA_FLAGS and runs ``pytest -m multidevice``.
"""
import importlib.util
import sys

import pytest

pytest.importorskip("numpy", reason="numpy is required for the test suite")
jax = pytest.importorskip("jax", reason="jax is required for the test suite")

if importlib.util.find_spec("hypothesis") is None:
    import _hypothesis_fallback  # tests/ is on sys.path during collection

    mod = _hypothesis_fallback.build_module()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies

jax.config.update("jax_enable_x64", True)


def pytest_collection_modifyitems(config, items):
    if len(jax.devices()) >= 2:
        return
    skip = pytest.mark.skip(
        reason="needs >=2 jax devices (run with "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8)"
    )
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
