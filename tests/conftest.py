"""Test-wide config.

x64 is enabled for the numerical-linear-algebra substrate (FEM / Cholesky /
FETI convergence checks need it). Model code passes explicit dtypes so the
LM smoke tests are unaffected. Device count stays at 1 — only the dry-run
launcher (a separate process) requests 512 placeholder devices.
"""
import jax

jax.config.update("jax_enable_x64", True)
