"""Public-API surface tests: the FetiConfig front door, the deprecation
shim for the pre-FetiConfig keyword style, and golden signature snapshots
so accidental API drift fails loudly."""
import dataclasses
import inspect

import numpy as np
import pytest

import repro.core
import repro.feti
from repro.core import SchurAssemblyConfig
from repro.fem.decomposition import decompose_elasticity_problem
from repro.feti import FetiConfig, FetiSolver, as_feti_config
from repro.feti.assembly import preprocess_cluster
from repro.feti.config import _coerce_config


# ------------------------------------------------------ FetiConfig ----

def test_config_sugar_not_deprecated():
    """None / "auto" / a bare SchurAssemblyConfig are blessed shorthand."""
    assert as_feti_config(None) == FetiConfig()
    assert as_feti_config("auto").schur == "auto"
    cfg = SchurAssemblyConfig(block_size=8)
    assert as_feti_config(cfg).schur is cfg
    fc = FetiConfig(preconditioner="dirichlet")
    assert as_feti_config(fc) is fc
    with pytest.raises(TypeError, match="FetiConfig"):
        as_feti_config(42)


def test_config_validation():
    with pytest.raises(ValueError, match="mode"):
        FetiConfig(mode="both")
    with pytest.raises(ValueError, match="preconditioner"):
        FetiConfig(preconditioner="jacobi")
    with pytest.raises(ValueError, match="storage"):
        FetiConfig(storage="sparse")
    with pytest.raises(ValueError, match="schur"):
        FetiConfig(schur="fastest")
    with pytest.raises(ValueError, match="share_factor"):
        FetiConfig(share_factor="maybe")


def test_old_kwargs_warn_and_map():
    with pytest.warns(DeprecationWarning, match="FetiConfig"):
        fc = _coerce_config(None, {"explicit": False, "dirichlet": True,
                                   "ordering": "rcm"}, "caller")
    assert fc.mode == "implicit"
    assert fc.preconditioner == "dirichlet"
    assert fc.ordering == "rcm"
    with pytest.raises(TypeError, match="unexpected keyword"):
        _coerce_config(None, {"blocksize": 8}, "caller")


def test_old_and_new_style_bit_identical():
    """Satellite check: the deprecated keyword style routes through the
    exact same preprocessing as the FetiConfig style — every device stack
    in the ClusterState is bit-identical."""
    prob = decompose_elasticity_problem(2, (2, 2), (3, 3))
    new = preprocess_cluster(
        prob, FetiConfig(mode="explicit", preconditioner="dirichlet"))
    with pytest.warns(DeprecationWarning):
        old = preprocess_cluster(prob, None, explicit=True, dirichlet=True)
    assert new.cfg == old.cfg
    assert np.array_equal(np.asarray(new.F), np.asarray(old.F))
    assert np.array_equal(np.asarray(new.Sb), np.asarray(old.Sb))
    Ln, Lo = new.L, old.L
    if hasattr(Ln, "values"):
        Ln, Lo = Ln.values, Lo.values
    assert np.array_equal(np.asarray(Ln), np.asarray(Lo))
    assert np.array_equal(new.node_perm, old.node_perm)
    assert new.shared_factor == old.shared_factor

    with pytest.warns(DeprecationWarning):
        s_old = FetiSolver(prob, preconditioner="dirichlet")
    s_new = FetiSolver(prob, FetiConfig(preconditioner="dirichlet"))
    assert s_old.config == s_new.config


# ------------------------------------------------------ re-exports ----

def test_feti_public_names():
    expected = {
        "BoundaryInteriorSplit", "ClusterState", "CoarseProblem",
        "FetiConfig", "FetiManySolution", "FetiSolution", "FetiSolver",
        "PCPGManyResult", "PCPGResult", "StageGraph", "StageSpec",
        "as_feti_config", "assemble_dirichlet_schur",
        "boundary_interior_split", "build_coarse_problem",
        "dirichlet_preconditioner", "dirichlet_preconditioner_many",
        "dual_rhs", "dual_rhs_many", "explicit_dual_apply",
        "explicit_dual_apply_many", "implicit_dual_apply",
        "implicit_dual_apply_many", "lumped_preconditioner",
        "lumped_preconditioner_many", "pcpg", "pcpg_many",
        "preprocess_cluster", "solve_many",
    }
    assert set(repro.feti.__all__) == expected
    for name in expected:
        assert hasattr(repro.feti, name), name


def test_core_exports_stage_graph():
    for name in ("StageSpec", "StageGraph", "GraphPlan", "ResolvedStage"):
        assert name in repro.core.__all__
        assert hasattr(repro.core, name)


# ------------------------------------------- golden signature snapshot ----

def test_entrypoint_signatures_golden():
    """The redesigned entry points all take (problem, config=None,
    **deprecated) — one front door, no keyword sprawl."""
    from repro.feti.assembly import make_cluster_preprocessor

    assert str(inspect.signature(preprocess_cluster)) == (
        "(problem: 'FetiProblem', config=None, **deprecated) "
        "-> 'ClusterState'")
    assert str(inspect.signature(make_cluster_preprocessor)) \
        == "(problem: 'FetiProblem', config=None, **deprecated)"
    assert str(inspect.signature(FetiSolver.__init__)) \
        == "(self, problem: 'FetiProblem', config=None, **deprecated)"
    assert str(inspect.signature(repro.feti.solve_many)) == (
        "(problem: 'FetiProblem', loads, config=None, *, "
        "tol: 'float' = 1e-09, max_iter: 'int' = 2000, "
        "rhs_unit: 'int' = 1) -> 'FetiManySolution'")


def test_feticonfig_fields_golden():
    fields = {f.name: f for f in dataclasses.fields(FetiConfig)}
    assert list(fields) == [
        "schur", "mode", "preconditioner", "ordering", "storage",
        "measure", "plan_cache", "dtype", "mesh", "share_factor",
    ]
    defaults = {n: f.default for n, f in fields.items()
                if f.default is not dataclasses.MISSING}
    assert defaults["mode"] == "explicit"
    assert defaults["preconditioner"] == "lumped"
    assert defaults["ordering"] == "nd"
    assert defaults["share_factor"] == "auto"
    assert FetiConfig.__dataclass_params__.frozen


def test_stagespec_fields_golden():
    from repro.core import StageSpec

    assert [f.name for f in dataclasses.fields(StageSpec)] == [
        "name", "builder", "fingerprint", "n", "storage", "dtype_bytes",
        "block_sizes", "share_factor_of", "measure",
    ]
