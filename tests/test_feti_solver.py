"""End-to-end FETI validation: the decomposed PCPG solve must reproduce the
undecomposed global sparse solve, for 2D and 3D, implicit and explicit dual
operators, every SC assembly variant, and both workloads (scalar heat with
kernel dim 1, vector elasticity with rigid-body kernel dim 3/6)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SchurAssemblyConfig
from repro.fem import decompose_problem
from repro.feti import FetiConfig, FetiSolver
from repro.feti.assembly import preprocess_cluster
from repro.feti.operator import explicit_dual_apply, implicit_dual_apply


@pytest.fixture(scope="module", params=["heat", "elasticity"])
def prob2d(request):
    return decompose_problem(request.param, 2, (2, 2), (4, 4))


@pytest.fixture(scope="module", params=["heat", "elasticity"])
def prob3d(request):
    return decompose_problem(request.param, 3, (2, 2, 1), (2, 2, 2))


def _check_against_reference(prob, sol, rtol=1e-6):
    u_ref = prob.reference_solution()
    scale = np.abs(u_ref).max()
    np.testing.assert_allclose(sol.u_global, u_ref, atol=rtol * scale)
    # interface copies agree across subdomains
    nn = prob.n_global_dofs
    vals = [[] for _ in range(nn)]
    for i, sd in enumerate(prob.subdomains):
        for lid, g in enumerate(sd.dof_gids):
            vals[g].append(sol.u[i, lid])
    for g, vs in enumerate(vals):
        if len(vs) > 1:
            assert np.ptp(vs) < rtol * scale * 10, f"interface jump at DOF {g}"


@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_feti_2d_matches_global_solve(prob2d, mode):
    solver = FetiSolver(prob2d, FetiConfig(
        schur=SchurAssemblyConfig(block_size=8, rhs_block_size=8),
        mode=mode))
    sol = solver.solve(tol=1e-10)
    assert sol.converged
    _check_against_reference(prob2d, sol)


@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_feti_3d_matches_global_solve(prob3d, mode):
    solver = FetiSolver(prob3d, FetiConfig(
        schur=SchurAssemblyConfig(block_size=8, rhs_block_size=8),
        mode=mode))
    sol = solver.solve(tol=1e-10)
    assert sol.converged
    _check_against_reference(prob3d, sol)


def test_explicit_equals_implicit_operator(prob2d):
    """F applied explicitly (preassembled SC) == implicitly (eq. 11 vs 12)."""
    cfg = SchurAssemblyConfig(block_size=8, rhs_block_size=8)
    st = preprocess_cluster(prob2d, cfg)
    nl = prob2d.n_lambda
    rng = np.random.default_rng(0)
    lam = jnp.asarray(rng.standard_normal(nl))
    q_exp = explicit_dual_apply(st.F, st.lambda_ids, nl, lam)
    q_imp = implicit_dual_apply(st.L, st.Btp, st.lambda_ids, nl, lam)
    np.testing.assert_allclose(np.asarray(q_exp), np.asarray(q_imp),
                               rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("trsm_variant,syrk_variant", [
    ("dense", "dense"),
    ("rhs_split", "input_split"),
    ("factor_split", "output_split"),
])
def test_feti_all_assembly_variants(prob2d, trsm_variant, syrk_variant):
    cfg = SchurAssemblyConfig(trsm_variant=trsm_variant, syrk_variant=syrk_variant,
                              block_size=8, rhs_block_size=8)
    sol = FetiSolver(prob2d, cfg).solve(tol=1e-10)
    assert sol.converged
    _check_against_reference(prob2d, sol)


@pytest.mark.parametrize("ordering", ["nd", "rcm", "natural"])
def test_feti_orderings(prob2d, ordering):
    cfg = SchurAssemblyConfig(block_size=8, rhs_block_size=8)
    sol = FetiSolver(prob2d, FetiConfig(
        schur=cfg, ordering=ordering)).solve(tol=1e-10)
    assert sol.converged
    _check_against_reference(prob2d, sol)


def test_feti_unpreconditioned_converges(prob2d):
    cfg = SchurAssemblyConfig(block_size=8, rhs_block_size=8)
    sol = FetiSolver(prob2d, FetiConfig(
        schur=cfg, preconditioner="none")).solve(tol=1e-10)
    assert sol.converged
    _check_against_reference(prob2d, sol)


def test_lumped_preconditioner_stays_correct_and_bounded():
    """On tiny well-conditioned heat problems the lumped preconditioner need
    not win (its payoff is on large/ill-conditioned systems), but it must
    stay correct and not blow up the iteration count."""
    prob = decompose_problem("heat", 2, (3, 3), (4, 4))
    cfg = SchurAssemblyConfig(block_size=8, rhs_block_size=8)
    sol_pre = FetiSolver(prob, cfg).solve(tol=1e-9)
    sol_no = FetiSolver(prob, FetiConfig(
        schur=cfg, preconditioner="none")).solve(tol=1e-9)
    assert sol_pre.converged and sol_no.converged
    _check_against_reference(prob, sol_pre)
    assert sol_pre.iterations <= 3 * sol_no.iterations


def test_amortization_report():
    prob = decompose_problem("heat", 2, (2, 2), (4, 4))
    solver = FetiSolver(prob, SchurAssemblyConfig(block_size=8, rhs_block_size=8))
    solver.preprocess()
    rep = solver.amortization_report(
        t_assembly_s=1.0, t_implicit_iter_s=0.15, t_explicit_iter_s=0.05
    )
    assert rep["amortization_iterations"] == pytest.approx(10.0)
    assert rep["assembly_flops_per_subdomain"]["total"] > 0
