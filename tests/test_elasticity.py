"""Oracle test tier for the linear-elasticity FETI workload.

Pins the whole pipeline — assembly, rigid-body coarse space (kernel dim
3/6), dense and packed storage, single-device and sharded — against
undecomposed reference solves:

  * FETI elasticity solve == global scipy sparse solve (≤ 1e-8, 2D & 3D),
  * patch test: the P1 elasticity discretization reproduces affine
    displacement fields exactly,
  * kernel property: ‖K_i R_i‖ ≤ 1e-10 for every subdomain's rigid-body
    basis, and the fixing-DOF regularization is an exact generalized
    inverse,
  * decomposition invariants for vector (node-blocked) DOFs.

The slower 3D oracle solves carry the ``elasticity`` marker so CI lanes
can select them (``pytest -m elasticity``).
"""
import numpy as np
import pytest

from repro.core import SchurAssemblyConfig
from repro.fem import (
    assemble_scipy_csr,
    decompose_elasticity_problem,
    element_dofs,
    fixing_dofs_regularization,
    kernel_basis,
    p1_elasticity_stiffness,
    structured_mesh,
)
from repro.feti import FetiConfig, FetiSolver

elasticity = pytest.mark.elasticity

CFG = SchurAssemblyConfig(block_size=8, rhs_block_size=8)
CFG_P = SchurAssemblyConfig(block_size=8, rhs_block_size=8, storage="packed")


@pytest.fixture(scope="module")
def ela2d():
    return decompose_elasticity_problem(2, (2, 2), (4, 4))


@pytest.fixture(scope="module")
def ela2d_big():
    """The 8x8-element grid PR 4 had to pin down to 4x4: the old GᵀG
    coarse factor floored the f64 dual residual above 1e-10 here. With
    the QR coarse factor + the dirichlet preconditioner the tight
    tolerance is reachable again (docs/preconditioners.md §Floor)."""
    return decompose_elasticity_problem(2, (2, 2), (8, 8))


@pytest.fixture(scope="module")
def ela3d():
    return decompose_elasticity_problem(3, (2, 2, 1), (2, 2, 2))


def _oracle_error(prob, sol):
    u_ref = prob.reference_solution()
    return np.max(np.abs(sol.u_global - u_ref)) / np.abs(u_ref).max()


# --------------------------------------------------------------------------
# the oracle: FETI == undecomposed global solve, ≤ 1e-8
# --------------------------------------------------------------------------


# the dirichlet-preconditioned case runs the BIGGER grid (8x8 elements)
# the lumped case had to give up under the old coarse-factor floor
@pytest.mark.parametrize("mode", ["explicit", "implicit"])
@pytest.mark.parametrize("storage", ["dense", "packed"])
@pytest.mark.parametrize("precond,fixture", [
    ("lumped", "ela2d"),
    ("dirichlet", "ela2d_big"),
])
def test_feti_elasticity_2d_matches_oracle(request, precond, fixture, mode,
                                           storage):
    prob = request.getfixturevalue(fixture)
    sol = FetiSolver(prob, FetiConfig(
        schur=CFG, mode=mode, preconditioner=precond,
        storage=storage)).solve(tol=1e-10)
    assert sol.converged
    assert _oracle_error(prob, sol) <= 1e-8
    assert sol.alpha.shape == (prob.n_subdomains, 3)


@elasticity
@pytest.mark.parametrize("storage", ["dense", "packed"])
@pytest.mark.parametrize("precond", ["lumped", "dirichlet"])
def test_feti_elasticity_3d_matches_oracle(ela3d, storage, precond):
    sol = FetiSolver(ela3d, FetiConfig(
        schur=CFG, storage=storage,
        preconditioner=precond)).solve(tol=1e-10)
    assert sol.converged
    assert _oracle_error(ela3d, sol) <= 1e-8
    assert sol.alpha.shape == (ela3d.n_subdomains, 6)


@elasticity
def test_dirichlet_needs_fewer_iterations_than_lumped(ela2d_big):
    """The preconditioner-quality oracle: on the conditioned 8x8
    elasticity case the dirichlet-preconditioned PCPG needs strictly
    fewer iterations than lumped (measured ~30 vs ~44)."""
    sol_l = FetiSolver(ela2d_big, CFG).solve(tol=1e-10)
    sol_d = FetiSolver(ela2d_big, FetiConfig(
        schur=CFG, preconditioner="dirichlet")).solve(tol=1e-10)
    assert sol_l.converged and sol_d.converged
    assert sol_d.iterations < sol_l.iterations
    assert _oracle_error(ela2d_big, sol_d) <= 1e-8


def test_feti_elasticity_interface_continuity(ela2d):
    """Duplicated interface DOF copies agree across subdomains."""
    sol = FetiSolver(ela2d, CFG).solve(tol=1e-10)
    scale = np.abs(sol.u_global).max()
    vals: dict[int, list[float]] = {}
    for i, sd in enumerate(ela2d.subdomains):
        for lid, g in enumerate(sd.dof_gids):
            vals.setdefault(int(g), []).append(sol.u[i, lid])
    for g, vs in vals.items():
        if len(vs) > 1:
            assert np.ptp(vs) < 1e-8 * scale, f"interface jump at DOF {g}"


# --------------------------------------------------------------------------
# patch test: affine displacement fields are reproduced exactly
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dim", [2, 3])
def test_patch_affine_displacement_exact(dim):
    """P1 elasticity with full-boundary Dirichlet data from an affine field
    u(x) = A x + b reproduces that field to machine precision (constant
    strain, zero body force — the classical patch test)."""
    rng = np.random.default_rng(0)
    mesh = structured_mesh((3,) * dim)
    nn = mesh.n_nodes
    A = rng.standard_normal((dim, dim))
    b = rng.standard_normal(dim)
    u_aff = (mesh.coords @ A.T + b).reshape(-1)  # node-blocked DOFs

    Ke = np.asarray(p1_elasticity_stiffness(mesh.coords, mesh.elems,
                                            lam=1.3, mu=0.7))
    K = assemble_scipy_csr(nn * dim, element_dofs(mesh.elems, dim), Ke)

    on_bnd = np.any((mesh.coords == 0.0) | (mesh.coords == 1.0), axis=1)
    bnd_dofs = (np.flatnonzero(on_bnd)[:, None] * dim
                + np.arange(dim)).reshape(-1)
    free = np.setdiff1d(np.arange(nn * dim), bnd_dofs)

    import scipy.sparse.linalg as spla

    u = np.zeros(nn * dim)
    u[bnd_dofs] = u_aff[bnd_dofs]
    rhs = -K[free][:, bnd_dofs] @ u[bnd_dofs]  # zero body force
    u[free] = spla.spsolve(K[free][:, free].tocsc(), rhs)
    np.testing.assert_allclose(u, u_aff, rtol=0,
                               atol=1e-10 * np.abs(u_aff).max())


@pytest.mark.parametrize("dim", [2, 3])
def test_affine_fields_have_zero_interior_residual(dim):
    """K u_affine vanishes at interior DOFs (constant stress ⇒ zero
    internal force away from the boundary)."""
    mesh = structured_mesh((3,) * dim)
    Ke = np.asarray(p1_elasticity_stiffness(mesh.coords, mesh.elems))
    K = assemble_scipy_csr(mesh.n_nodes * dim,
                           element_dofs(mesh.elems, dim), Ke)
    rng = np.random.default_rng(1)
    u_aff = (mesh.coords @ rng.standard_normal((dim, dim)).T
             + rng.standard_normal(dim)).reshape(-1)
    r = K @ u_aff
    interior = ~np.any((mesh.coords == 0.0) | (mesh.coords == 1.0), axis=1)
    int_dofs = (np.flatnonzero(interior)[:, None] * dim
                + np.arange(dim)).reshape(-1)
    np.testing.assert_allclose(r[int_dofs], 0.0, atol=1e-12)


# --------------------------------------------------------------------------
# kernel property: K_i R_i = 0 and the regularization is exact
# --------------------------------------------------------------------------


@pytest.mark.parametrize("prob_fixture", ["ela2d", "ela3d"])
def test_property_kernel_annihilated_per_subdomain(prob_fixture, request):
    """‖K_i R_i‖ ≤ 1e-10 for every subdomain's rigid-body basis, and the
    basis is orthonormal with the right dimension."""
    prob = request.getfixturevalue(prob_fixture)
    k = prob.kernel_dim
    assert k == (3 if prob.dim == 2 else 6)
    for sd in prob.subdomains:
        assert sd.R.shape == (sd.n, k)
        assert np.abs(sd.K @ sd.R).max() <= 1e-10
        np.testing.assert_allclose(sd.R.T @ sd.R, np.eye(k), atol=1e-12)
        # kernel dimension is exactly k: K is SPSD with k zero eigenvalues
        w = np.linalg.eigvalsh(sd.K)
        assert w[k - 1] < 1e-10 < w[k]


@pytest.mark.parametrize("prob_fixture", ["ela2d", "ela3d"])
def test_fixing_dofs_regularization_exact_generalized_inverse(
        prob_fixture, request):
    """R[fixing_dofs] is invertible (the 3-2-1 fixture), K_reg is SPD, and
    K K_reg⁻¹ K == K — the exactness FETI's K⁺ relies on."""
    prob = request.getfixturevalue(prob_fixture)
    sd = prob.subdomains[0]
    Rf = sd.R[sd.fixing_dofs]
    assert Rf.shape == (prob.kernel_dim, prob.kernel_dim)
    assert np.abs(np.linalg.det(Rf)) > 1e-8
    Kreg = fixing_dofs_regularization(sd.K, sd.fixing_dofs)
    w = np.linalg.eigvalsh(Kreg)
    assert w[0] > 1e-10
    KpK = sd.K @ np.linalg.solve(Kreg, sd.K)
    np.testing.assert_allclose(KpK, sd.K, rtol=1e-9, atol=1e-9)


def test_heat_kernel_basis_through_same_code():
    """The generalized kernel_basis reproduces the heat constant."""
    r = kernel_basis(25, "heat")
    np.testing.assert_allclose(r, np.full((25, 1), 0.2), atol=1e-14)


# --------------------------------------------------------------------------
# decomposition invariants for vector DOFs
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dim,sub_grid,eps", [
    (2, (2, 2), (3, 3)),
    (3, (2, 2, 2), (2, 2, 2)),
])
def test_elasticity_decomposition_invariants(dim, sub_grid, eps):
    prob = decompose_elasticity_problem(dim, sub_grid, eps)
    assert prob.ndof_per_node == dim
    n_i = prob.subdomains[0].n
    assert n_i == dim * int(np.prod([e + 1 for e in eps]))

    counts = np.zeros(prob.n_lambda + 1, dtype=int)
    for sd in prob.subdomains:
        used = sd.lambda_ids[: sd.m]
        counts[used] += 1
        assert np.all(sd.lambda_ids[sd.m:] == prob.n_lambda)
        col_nnz = (sd.Bt[:, : sd.m] != 0).sum(axis=0)
        assert np.all(col_nnz == 1)
        assert np.all(sd.Bt[:, sd.m:] == 0)
        # node-blocked dof_gids expand the node gids
        np.testing.assert_array_equal(
            sd.dof_gids,
            (sd.node_gids[:, None] * dim + np.arange(dim)).reshape(-1))
    counts = counts[:-1]
    assert np.all((counts == 1) | (counts == 2))

    # gluing rows annihilate any globally-consistent DOF field
    u_glob = np.arange(prob.n_global_dofs, dtype=float)
    r = np.zeros(prob.n_lambda + 1)
    for sd in prob.subdomains:
        np.add.at(r, sd.lambda_ids, sd.Bt.T @ u_glob[sd.dof_gids])
    np.testing.assert_allclose(r[:-1][counts == 2], 0.0, atol=1e-9)


# --------------------------------------------------------------------------
# sharded elasticity (CI multidevice lane)
# --------------------------------------------------------------------------


@pytest.mark.multidevice
@pytest.mark.parametrize("storage", ["dense", "packed"])
def test_sharded_elasticity_matches_single_device(ela2d, storage):
    """The acceptance bar: the sharded elasticity solve reproduces the
    single-device one (same iterates) and both meet the oracle."""
    from repro.launch.mesh import make_feti_mesh

    mesh = make_feti_mesh()
    fc = FetiConfig(schur=CFG, storage=storage)
    sol_sh = FetiSolver(ela2d, fc.replace(mesh=mesh)).solve(tol=1e-10)
    sol1 = FetiSolver(ela2d, fc).solve(tol=1e-10)
    assert sol_sh.converged and sol1.converged
    assert sol_sh.iterations == sol1.iterations
    assert np.max(np.abs(sol_sh.u_global - sol1.u_global)) < 1e-9
    assert _oracle_error(ela2d, sol_sh) <= 1e-8
