"""Correctness of every TRSM/SYRK variant against dense oracles (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.linalg
from hypothesis import given, settings, strategies as st

from repro.core import (
    SchurAssemblyConfig,
    assemble_schur,
    build_stepped_meta,
    schur_dense_baseline,
    syrk_dense,
    syrk_input_split,
    syrk_output_split,
    trsm_dense,
    trsm_factor_split,
    trsm_rhs_split,
)
from repro.testing import (
    block_fill_mask_from_factor,
    random_feti_like_bt,
    random_lower_banded,
)


def _problem(n, m, bw, seed, block_size=16, rhs_block_size=8):
    rng = np.random.default_rng(seed)
    L = random_lower_banded(n, bw, rng)
    Bt = random_feti_like_bt(n, m, rng)
    meta = build_stepped_meta(Bt != 0, block_size=block_size,
                              rhs_block_size=rhs_block_size)
    Bp = Bt[:, meta.perm]  # stepped order
    return L, Bt, Bp, meta


@pytest.mark.parametrize("n,m,bw", [(64, 24, 8), (100, 40, 12), (37, 9, 5)])
def test_trsm_dense_matches_scipy(n, m, bw):
    L, _, Bp, _ = _problem(n, m, bw, seed=0)
    got = trsm_dense(jnp.asarray(L), jnp.asarray(Bp))
    want = scipy.linalg.solve_triangular(L, Bp, lower=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("variant", ["rhs_split", "factor_split"])
@pytest.mark.parametrize("n,m,bw,bs,cbs", [
    (64, 24, 8, 16, 8),
    (100, 40, 12, 32, 16),
    (63, 17, 9, 16, 5),   # ragged blocks
    (48, 48, 48, 8, 8),   # fully dense factor
])
def test_trsm_variants_match_dense(variant, n, m, bw, bs, cbs):
    L, _, Bp, meta = _problem(n, m, bw, seed=1, block_size=bs, rhs_block_size=cbs)
    want = trsm_dense(jnp.asarray(L), jnp.asarray(Bp))
    if variant == "rhs_split":
        got = trsm_rhs_split(jnp.asarray(L), jnp.asarray(Bp), meta)
    else:
        got = trsm_factor_split(jnp.asarray(L), jnp.asarray(Bp), meta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)


def test_trsm_factor_split_pruning_matches():
    n, m = 96, 30
    L, _, Bp, meta = _problem(n, m, 10, seed=2, block_size=16)
    mask = block_fill_mask_from_factor(L, meta.block_size)
    got = trsm_factor_split(jnp.asarray(L), jnp.asarray(Bp), meta, block_mask=mask)
    want = trsm_dense(jnp.asarray(L), jnp.asarray(Bp))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)


def test_trsm_preserves_zeros_above_pivots():
    """The paper's fundamental observation: forward substitution propagates
    downward, so zeros above the column pivots survive TRSM."""
    n, m = 80, 25
    L, _, Bp, meta = _problem(n, m, 9, seed=3)
    Y = np.asarray(trsm_dense(jnp.asarray(L), jnp.asarray(Bp)))
    for j in range(m):
        p = int(meta.pivots[j])
        if p < n:
            np.testing.assert_array_equal(Y[:p, j], 0.0)


@pytest.mark.parametrize("variant", ["input_split", "output_split"])
@pytest.mark.parametrize("n,m,bs,cbs", [
    (64, 24, 16, 8),
    (100, 40, 32, 16),
    (63, 17, 16, 5),
    (48, 48, 8, 8),
])
def test_syrk_variants_match_dense(variant, n, m, bs, cbs):
    L, _, Bp, meta = _problem(n, m, 8, seed=4, block_size=bs, rhs_block_size=cbs)
    Y = trsm_dense(jnp.asarray(L), jnp.asarray(Bp))
    want = syrk_dense(Y)
    fn = syrk_input_split if variant == "input_split" else syrk_output_split
    got = fn(Y, meta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)
    # result symmetric
    np.testing.assert_allclose(np.asarray(got), np.asarray(got).T,
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("trsm_variant", ["dense", "rhs_split", "factor_split"])
@pytest.mark.parametrize("syrk_variant", ["dense", "input_split", "output_split"])
def test_assembly_all_variant_combinations(trsm_variant, syrk_variant):
    """Full pipeline (permute -> TRSM -> SYRK -> permute back) across the
    whole paper §3 design space equals the dense baseline of §3.1."""
    n, m = 72, 28
    L, Bt, _, meta = _problem(n, m, 8, seed=5, block_size=16, rhs_block_size=8)
    mask = block_fill_mask_from_factor(L, meta.block_size)
    cfg = SchurAssemblyConfig(trsm_variant=trsm_variant, syrk_variant=syrk_variant,
                              block_size=16, rhs_block_size=8)
    got = assemble_schur(jnp.asarray(L), jnp.asarray(Bt), meta, cfg, block_mask=mask)
    want = schur_dense_baseline(jnp.asarray(L), jnp.asarray(Bt))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-8, atol=1e-8)


def test_assembly_matches_mathematical_definition():
    """F̃ = B̃ K⁻¹ B̃ᵀ with K = L Lᵀ (paper eq. 14)."""
    n, m = 60, 20
    L, Bt, _, meta = _problem(n, m, 7, seed=6)
    cfg = SchurAssemblyConfig(block_size=16, rhs_block_size=8)
    got = assemble_schur(jnp.asarray(L), jnp.asarray(Bt), meta, cfg)
    K = L @ L.T
    want = Bt.T @ np.linalg.solve(K, Bt)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-8, atol=1e-8)


def test_assembly_jits_and_is_stable_under_jit():
    n, m = 64, 24
    L, Bt, _, meta = _problem(n, m, 8, seed=7)
    cfg = SchurAssemblyConfig(block_size=16, rhs_block_size=8)
    from repro.core import make_assembler

    fn = jax.jit(make_assembler(meta, cfg))
    got = fn(jnp.asarray(L), jnp.asarray(Bt))
    want = schur_dense_baseline(jnp.asarray(L), jnp.asarray(Bt))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-8, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(12, 80),
    m=st.integers(2, 40),
    bw=st.integers(1, 16),
    bs=st.integers(4, 24),
    cbs=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_full_pipeline(n, m, bw, bs, cbs, seed):
    """Property: for ANY random factor/pattern/blocking, the optimized
    assembly equals B K⁻¹ Bᵀ."""
    rng = np.random.default_rng(seed)
    L = random_lower_banded(n, min(bw, n - 1), rng)
    Bt = random_feti_like_bt(n, m, rng)
    meta = build_stepped_meta(Bt != 0, block_size=bs, rhs_block_size=cbs)
    mask = block_fill_mask_from_factor(L, bs)
    cfg = SchurAssemblyConfig(trsm_variant="factor_split",
                              syrk_variant="output_split",
                              block_size=bs, rhs_block_size=cbs)
    got = assemble_schur(jnp.asarray(L), jnp.asarray(Bt), meta, cfg, block_mask=mask)
    K = L @ L.T
    want = Bt.T @ np.linalg.solve(K, Bt)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-7, atol=1e-7)
