"""Pallas stepped kernels vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps + hypothesis property tests per the kernel contract:
every (pattern, block size, dtype) must match ref.py to tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SchurAssemblyConfig, assemble_schur, build_stepped_meta
from repro.core.schur import schur_dense_baseline
from repro.kernels import ops
from repro.kernels.ref import syrk_ref, trsm_ref
from repro.testing import random_feti_like_bt, random_lower_banded

TOLS = {
    jnp.float64.dtype: dict(rtol=1e-9, atol=1e-9),
    jnp.float32.dtype: dict(rtol=2e-4, atol=2e-4),
    jnp.bfloat16.dtype: dict(rtol=5e-2, atol=5e-2),
}


def _problem(n, m, bw, seed, bs, bm, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    L = jnp.asarray(random_lower_banded(n, bw, rng), dtype)
    Bt = random_feti_like_bt(n, m, rng)
    meta = build_stepped_meta(Bt != 0, block_size=bs, rhs_block_size=bm)
    Bp = jnp.asarray(Bt[:, meta.perm], dtype)
    return L, Bp, meta


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("n,m,bs,bm", [
    (64, 32, 16, 8),
    (64, 32, 8, 8),
    (96, 40, 32, 16),   # padding needed on m (40 -> 48)
    (60, 28, 16, 8),    # padding needed on n (60 -> 64)
    (128, 128, 32, 32),
])
def test_pallas_trsm_matches_ref(n, m, bs, bm, dtype):
    L, Bp, meta = _problem(n, m, 10, seed=0, bs=bs, bm=bm, dtype=dtype)
    got = ops.stepped_trsm(L, Bp, meta, interpret=True)
    want = trsm_ref(L, Bp)
    tol = TOLS[jnp.dtype(dtype)]
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("n,m,bs,bm", [
    (64, 32, 16, 8),
    (96, 40, 32, 16),
    (60, 28, 16, 8),
    (128, 128, 32, 32),
])
def test_pallas_syrk_matches_ref(n, m, bs, bm, dtype):
    L, Bp, meta = _problem(n, m, 10, seed=1, bs=bs, bm=bm, dtype=dtype)
    Y = trsm_ref(L, Bp)
    got = ops.stepped_syrk(Y, meta, interpret=True)
    want = syrk_ref(Y)
    tol = TOLS[jnp.dtype(dtype)]
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), **tol)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got).T,
                               rtol=0, atol=0)


def test_pallas_trsm_bf16_tolerant():
    L, Bp, meta = _problem(64, 32, 6, seed=2, bs=16, bm=8, dtype=jnp.bfloat16)
    got = ops.stepped_trsm(L, Bp, meta, interpret=True)
    want = trsm_ref(L.astype(jnp.float64), Bp.astype(jnp.float64))
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64),
                               **TOLS[jnp.bfloat16.dtype])


def test_pallas_trsm_skips_zero_region():
    """Rows above each stripe's pivot must stay exactly zero (not just
    small): the kernel never writes the skipped region."""
    L, Bp, meta = _problem(96, 48, 8, seed=3, bs=16, bm=8)
    got = np.asarray(ops.stepped_trsm(L, Bp, meta, interpret=True))
    for c in range(meta.num_col_blocks):
        c0, c1 = meta.col_block(c)
        blk_start = (int(meta.col_starts[c]) // meta.block_size) * meta.block_size
        assert np.all(got[:blk_start, c0:c1] == 0.0)


def test_full_assembly_with_pallas_backend():
    """SchurAssemblyConfig(use_pallas=True) end-to-end == dense baseline."""
    n, m = 96, 40
    rng = np.random.default_rng(4)
    L = jnp.asarray(random_lower_banded(n, 12, rng))
    Bt_np = random_feti_like_bt(n, m, rng)
    Bt = jnp.asarray(Bt_np)
    meta = build_stepped_meta(Bt_np != 0, block_size=16, rhs_block_size=8)
    cfg = SchurAssemblyConfig(block_size=16, rhs_block_size=8,
                              use_pallas=True, interpret=True)
    got = assemble_schur(L, Bt, meta, cfg)
    want = schur_dense_baseline(L, Bt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-9)


def test_invert_diag_blocks():
    rng = np.random.default_rng(5)
    L = jnp.asarray(random_lower_banded(64, 10, rng))
    inv = ops.invert_diag_blocks(L, 16)
    for k in range(4):
        blk = np.asarray(L)[16 * k : 16 * (k + 1), 16 * k : 16 * (k + 1)]
        np.testing.assert_allclose(np.asarray(inv[k]) @ blk, np.eye(16),
                                   rtol=1e-9, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(16, 80),
    m=st.integers(4, 40),
    bw=st.integers(1, 12),
    bs=st.sampled_from([8, 16, 32]),
    bm=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_pallas_pipeline(n, m, bw, bs, bm, seed):
    """Property: Pallas TRSM∘SYRK == dense oracle for any stepped pattern."""
    rng = np.random.default_rng(seed)
    L = jnp.asarray(random_lower_banded(n, min(bw, n - 1), rng))
    Bt = random_feti_like_bt(n, m, rng)
    meta = build_stepped_meta(Bt != 0, block_size=bs, rhs_block_size=bm)
    Bp = jnp.asarray(Bt[:, meta.perm])
    Y = ops.stepped_trsm(L, Bp, meta, interpret=True)
    F = ops.stepped_syrk(Y, meta, interpret=True)
    want = syrk_ref(trsm_ref(L, Bp))
    np.testing.assert_allclose(np.asarray(F), np.asarray(want),
                               rtol=1e-8, atol=1e-8)
